//! Adaptive sparse/dense frontier representation.
//!
//! The paper's hybrid scheduler switches push/pull per iteration
//! (Algorithm 2, Fig 8); Beamer-style direction optimization pairs that
//! direction switch with a *representation* switch: a small frontier is
//! a queue the hardware pops from a FIFO (O(frontier) P1 work), a large
//! one is the dense BRAM bitmap it scans words-at-a-time (O(|V|/64)).
//! [`Frontier`] gives every engine both representations behind one type:
//!
//! * **Dense view** — the [`Bitset`] is *always* maintained, so O(1)
//!   membership tests (pull's parent check, the edge-centric scatter)
//!   work in either representation.
//! * **Sparse view** — while the frontier stays below its
//!   `sparse_cap`, inserts also append to a vertex list in discovery
//!   order (the hardware's next-frontier FIFO). Overflowing the cap
//!   drops the list and the frontier stays dense for its lifetime —
//!   this is how the adaptive policy "decides" the representation: the
//!   cap is set per iteration by the scheduler
//!   ([`crate::sched::ReprPolicy`], owned by the same `ModePolicy`
//!   that picks push vs pull), and the staged frontier lands sparse
//!   exactly when its size ends up under the threshold.
//! * **Insert-time signals** — every insert accumulates the vertex's
//!   out-degree, so the scheduler's `frontier_edges` signal (and the
//!   Graph500 `traversed_edges` total) come for free; the driver no
//!   longer rescans the new frontier between iterations.
//!
//! Clearing a sparse frontier only zeroes the bitmap words it touched
//! ([`Bitset::clear_words_touched`]), keeping per-iteration reset cost
//! O(frontier) instead of O(|V|/64) — the BRAM-clear analog of the
//! targeted invalidate GraphScale-style frameworks use to scale.

use crate::graph::VertexId;
use crate::util::Bitset;

/// Default adaptive threshold divisor: a frontier is kept sparse while
/// it holds fewer than `|V| / DEFAULT_SPARSE_DIVISOR` vertices. The
/// value mirrors Beamer's pull→push `beta`-style fraction; sweeps can
/// override it through [`crate::sched::ReprPolicy::Adaptive`].
pub const DEFAULT_SPARSE_DIVISOR: u32 = 32;

/// Floor on the sparse capacity so tiny graphs never ping-pong
/// representations.
const SPARSE_CAP_FLOOR: usize = 64;

/// Sparse capacity for an `n`-vertex frontier under threshold
/// `|V| / divisor`, with the small-graph floor applied.
pub fn adaptive_sparse_cap(n: usize, divisor: u32) -> usize {
    (n / (divisor.max(1) as usize)).max(SPARSE_CAP_FLOOR)
}

/// Default sparse capacity for an `n`-vertex frontier.
pub fn default_sparse_cap(n: usize) -> usize {
    adaptive_sparse_cap(n, DEFAULT_SPARSE_DIVISOR)
}

/// Which representation a [`Frontier`] currently holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontierRepr {
    /// Vertex list (discovery order) + bitmap — the frontier-FIFO path.
    Sparse,
    /// Bitmap only — the BRAM-scan path.
    Dense,
}

/// A BFS frontier with an adaptive sparse/dense representation.
///
/// All storage is retained across [`clear`](Self::clear) calls (the
/// BRAM-clear pattern of [`super::SearchState::reset_for_root`]): no
/// allocation on the steady-state path once list and scratch buffers
/// have grown to their working size.
#[derive(Clone, Debug)]
pub struct Frontier {
    /// Dense bitmap — authoritative membership in both representations.
    bits: Bitset,
    /// Sparse vertex list in insertion (discovery) order; valid only
    /// while `sparse` is true.
    verts: Vec<VertexId>,
    /// Whether `verts` mirrors the bitmap.
    sparse: bool,
    /// Inserts beyond this many vertices overflow the list to dense.
    sparse_cap: usize,
    /// Scratch buffer of touched word indices for targeted clears.
    word_scratch: Vec<usize>,
    /// Vertices in the frontier.
    len: u64,
    /// Sum of out-degrees of the frontier (the scheduler's
    /// push→pull switching signal), accumulated at insert time.
    edges: u64,
}

impl Frontier {
    /// Empty sparse frontier for an `n`-vertex graph with the default
    /// adaptive capacity.
    pub fn new(n: usize) -> Self {
        Self::with_sparse_cap(n, default_sparse_cap(n))
    }

    /// Empty sparse frontier with an explicit sparse capacity (0 means
    /// the first insert already lands dense).
    pub fn with_sparse_cap(n: usize, sparse_cap: usize) -> Self {
        Self {
            bits: Bitset::new(n),
            verts: Vec::new(),
            sparse: true,
            sparse_cap,
            word_scratch: Vec::new(),
            len: 0,
            edges: 0,
        }
    }

    /// Number of vertices the frontier is sized for (graph |V|, not the
    /// frontier population — see [`len`](Self::len)).
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.bits.len()
    }

    /// Vertices currently in the frontier.
    #[inline]
    pub fn len(&self) -> u64 {
        self.len
    }

    /// True when no vertex is in the frontier.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Sum of out-degrees of the frontier's vertices, as accumulated by
    /// [`insert`](Self::insert).
    #[inline]
    pub fn edges(&self) -> u64 {
        self.edges
    }

    /// Current representation.
    #[inline]
    pub fn repr(&self) -> FrontierRepr {
        if self.sparse {
            FrontierRepr::Sparse
        } else {
            FrontierRepr::Dense
        }
    }

    /// True while the sparse vertex list is valid.
    #[inline]
    pub fn is_sparse(&self) -> bool {
        self.sparse
    }

    /// The sparse capacity in effect.
    #[inline]
    pub fn sparse_cap(&self) -> usize {
        self.sparse_cap
    }

    /// Set the sparse capacity for the vertices staged next (the
    /// driver calls this with the scheduler's per-iteration threshold).
    /// If the list already exceeds the new cap the frontier converts to
    /// dense in place; an existing dense frontier is left dense.
    pub fn set_sparse_cap(&mut self, cap: usize) {
        self.sparse_cap = cap;
        if self.sparse && self.verts.len() > cap {
            self.to_dense();
        }
    }

    /// O(1) membership test (valid in both representations).
    #[inline]
    pub fn contains(&self, v: usize) -> bool {
        self.bits.get(v)
    }

    /// Insert `v` with its out-degree. Duplicate inserts are no-ops
    /// (the bitmap deduplicates), so pull-mode engines may stage the
    /// same discovery defensively without double-counting `len`/`edges`.
    /// Returns true when `v` was newly inserted.
    pub fn insert(&mut self, v: VertexId, degree: u64) -> bool {
        if self.bits.test_and_set(v as usize) {
            return false;
        }
        self.len += 1;
        self.edges += degree;
        if self.sparse {
            if self.verts.len() >= self.sparse_cap {
                // Overflow: this frontier is dense from here on. The
                // bitmap already holds every inserted vertex, so the
                // list is simply dropped (capacity retained).
                self.sparse = false;
                self.verts.clear();
            } else {
                self.verts.push(v);
            }
        }
        true
    }

    /// Word-batched insert: stage every vertex in `mask` (bit `b` of
    /// word `wi` = vertex `wi * 64 + b`) in one bitmap OR, then account
    /// `len`/`edges` and the sparse list only for the bits that were
    /// actually new. Exactly equivalent to calling
    /// [`insert`](Self::insert) for each mask bit in ascending order —
    /// same discovery order, same overflow-to-dense behavior — but the
    /// membership test-and-set is a single word op. `degree_of` is
    /// invoked once per *newly* inserted vertex. Returns the mask of
    /// newly inserted bits.
    pub fn insert_word(
        &mut self,
        wi: usize,
        mask: u64,
        mut degree_of: impl FnMut(VertexId) -> u64,
    ) -> u64 {
        if mask == 0 {
            return 0;
        }
        let newly = self.bits.test_and_set_word(wi, mask);
        let mut m = newly;
        while m != 0 {
            let v = ((wi << 6) + m.trailing_zeros() as usize) as VertexId;
            m &= m - 1;
            self.len += 1;
            self.edges += degree_of(v);
            if self.sparse {
                if self.verts.len() >= self.sparse_cap {
                    self.sparse = false;
                    self.verts.clear();
                } else {
                    self.verts.push(v);
                }
            }
        }
        newly
    }

    /// Walk the frontier like [`iter`](Self::iter) but, on the sparse
    /// (FIFO) path, drive a two-stage software-prefetch pipeline: for
    /// the vertex `far` positions ahead call `prefetch_far` (pull its
    /// `row_ptr` entry toward L1), and for the vertex `near` positions
    /// ahead call `prefetch_near` (its offset is resident by then, so
    /// the `col_idx` stream can be seeded). The dense path is a linear
    /// bitmap scan the hardware prefetcher already covers, so the
    /// callbacks are not used there. Visit order is identical to
    /// [`iter`](Self::iter) in both representations.
    pub fn for_each_with_lookahead(
        &self,
        far: usize,
        mut prefetch_far: impl FnMut(usize),
        near: usize,
        mut prefetch_near: impl FnMut(usize),
        mut f: impl FnMut(usize),
    ) {
        if let Some(verts) = self.sparse_verts() {
            for &v in verts.iter().take(far) {
                prefetch_far(v as usize);
            }
            for (i, &v) in verts.iter().enumerate() {
                if let Some(&ahead) = verts.get(i + far) {
                    prefetch_far(ahead as usize);
                }
                if let Some(&ahead) = verts.get(i + near) {
                    prefetch_near(ahead as usize);
                }
                f(v as usize);
            }
        } else {
            for v in self.bits.iter_ones() {
                f(v);
            }
        }
    }

    /// The dense bitmap view (always valid, either representation).
    #[inline]
    pub fn bits(&self) -> &Bitset {
        &self.bits
    }

    /// The sparse vertex list in discovery order, when the frontier is
    /// sparse.
    #[inline]
    pub fn sparse_verts(&self) -> Option<&[VertexId]> {
        if self.sparse {
            Some(&self.verts)
        } else {
            None
        }
    }

    /// Iterate the frontier's vertices: list order when sparse (the
    /// frontier FIFO), ascending bit order when dense (the BRAM scan).
    pub fn iter(&self) -> FrontierIter<'_> {
        if self.sparse {
            FrontierIter::Sparse(self.verts.iter())
        } else {
            FrontierIter::Dense(self.bits.iter_ones())
        }
    }

    /// In-place dense→sparse conversion: rebuild the vertex list from
    /// the bitmap (ascending order). `len`/`edges` are unchanged — they
    /// are representation-independent. No-op when already sparse.
    pub fn to_sparse(&mut self) {
        if self.sparse {
            return;
        }
        self.verts.clear();
        for v in self.bits.iter_ones() {
            self.verts.push(v as VertexId);
        }
        self.sparse = true;
    }

    /// In-place sparse→dense conversion: drop the list (the bitmap is
    /// already authoritative). No-op when already dense.
    pub fn to_dense(&mut self) {
        self.sparse = false;
        self.verts.clear();
    }

    /// Empty the frontier in place, retaining every buffer's capacity.
    /// A sparse frontier clears only the bitmap words it touched
    /// ([`Bitset::clear_words_touched`], O(frontier)); a dense one pays
    /// the full word sweep. The cleared frontier is sparse (an empty
    /// list is trivially valid).
    pub fn clear(&mut self) {
        if self.sparse {
            self.word_scratch.clear();
            self.word_scratch
                .extend(self.verts.iter().map(|&v| (v as usize) >> 6));
            self.bits.clear_words_touched(&self.word_scratch);
        } else {
            self.bits.clear_all();
        }
        self.verts.clear();
        self.sparse = true;
        self.len = 0;
        self.edges = 0;
    }
}

/// Iterator over a [`Frontier`]'s vertices (see [`Frontier::iter`]).
pub enum FrontierIter<'a> {
    /// Discovery-order walk of the sparse list.
    Sparse(std::slice::Iter<'a, VertexId>),
    /// Ascending-order scan of the dense bitmap.
    Dense(crate::util::bitset::OnesIter<'a>),
}

impl<'a> Iterator for FrontierIter<'a> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        match self {
            FrontierIter::Sparse(it) => it.next().map(|&v| v as usize),
            FrontierIter::Dense(it) => it.next(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_tracks_len_edges_and_membership() {
        let mut f = Frontier::new(256);
        assert!(f.is_empty());
        assert!(f.insert(3, 5));
        assert!(f.insert(200, 7));
        assert_eq!(f.len(), 2);
        assert_eq!(f.edges(), 12);
        assert!(f.contains(3) && f.contains(200) && !f.contains(4));
        assert_eq!(f.repr(), FrontierRepr::Sparse);
        assert_eq!(f.sparse_verts(), Some(&[3u32, 200][..]));
        assert_eq!(f.iter().collect::<Vec<_>>(), vec![3, 200]);
    }

    #[test]
    fn duplicate_insert_is_a_noop() {
        // Pull-mode semantics: staging the same child twice must not
        // double-count the scheduler signals in either representation.
        let mut f = Frontier::with_sparse_cap(128, 128);
        assert!(f.insert(9, 4));
        assert!(!f.insert(9, 4));
        assert_eq!(f.len(), 1);
        assert_eq!(f.edges(), 4);
        assert_eq!(f.sparse_verts().unwrap().len(), 1);
        f.to_dense();
        assert!(!f.insert(9, 4));
        assert_eq!(f.len(), 1);
        assert_eq!(f.edges(), 4);
    }

    #[test]
    fn overflow_converts_to_dense_and_keeps_counters() {
        let mut f = Frontier::with_sparse_cap(1024, 4);
        for v in 0..4u32 {
            f.insert(v * 10, 2);
        }
        assert!(f.is_sparse());
        // Fifth insert overflows the cap: list dropped, bitmap kept.
        f.insert(999, 2);
        assert_eq!(f.repr(), FrontierRepr::Dense);
        assert!(f.sparse_verts().is_none());
        assert_eq!(f.len(), 5);
        assert_eq!(f.edges(), 10);
        for v in [0usize, 10, 20, 30, 999] {
            assert!(f.contains(v));
        }
        // Dense iteration is ascending.
        assert_eq!(f.iter().collect::<Vec<_>>(), vec![0, 10, 20, 30, 999]);
    }

    #[test]
    fn round_trip_sparse_dense_sparse_preserves_contents() {
        let mut f = Frontier::with_sparse_cap(512, 512);
        // Insert out of order: sparse list keeps discovery order.
        for &v in &[64u32, 3, 500, 65] {
            f.insert(v, 1);
        }
        assert_eq!(f.sparse_verts(), Some(&[64u32, 3, 500, 65][..]));
        f.to_dense();
        assert_eq!(f.len(), 4);
        assert_eq!(f.edges(), 4);
        assert_eq!(f.iter().collect::<Vec<_>>(), vec![3, 64, 65, 500]);
        // Dense→sparse rebuilds the list in ascending order.
        f.to_sparse();
        assert_eq!(f.sparse_verts(), Some(&[3u32, 64, 65, 500][..]));
        assert_eq!(f.len(), 4);
        assert_eq!(f.edges(), 4);
    }

    #[test]
    fn clear_is_targeted_when_sparse_and_full_when_dense() {
        let mut f = Frontier::with_sparse_cap(4096, 8);
        f.insert(0, 1);
        f.insert(4000, 1);
        f.clear();
        assert!(f.is_empty());
        assert_eq!(f.edges(), 0);
        assert!(f.bits().none());
        assert!(f.is_sparse());
        // Dense clear also fully resets.
        f.set_sparse_cap(0);
        f.insert(17, 3);
        assert_eq!(f.repr(), FrontierRepr::Dense);
        f.clear();
        assert!(f.bits().none());
        assert!(f.is_sparse());
        assert_eq!(f.len(), 0);
    }

    #[test]
    fn insert_word_matches_scalar_inserts() {
        // Word-batched insert must be indistinguishable from the
        // ascending scalar insert loop: counters, order, overflow.
        let degree = |v: VertexId| u64::from(v) + 1;
        let mut batched = Frontier::with_sparse_cap(256, 256);
        let mut scalar = Frontier::with_sparse_cap(256, 256);
        scalar.insert(70, degree(70));
        batched.insert(70, degree(70));
        let mask = 1u64 << 2 | 1 << 6 | 1 << 63;
        let newly = batched.insert_word(1, mask, degree);
        // Bit 6 of word 1 = vertex 70 was already present.
        assert_eq!(newly, 1u64 << 2 | 1 << 63);
        for bit in [2usize, 6, 63] {
            let v = (64 + bit) as VertexId;
            scalar.insert(v, degree(v));
        }
        assert_eq!(batched.len(), scalar.len());
        assert_eq!(batched.edges(), scalar.edges());
        assert_eq!(batched.sparse_verts(), scalar.sparse_verts());
        assert_eq!(batched.insert_word(1, mask, degree), 0);
    }

    #[test]
    fn insert_word_overflows_to_dense_like_insert() {
        let mut f = Frontier::with_sparse_cap(256, 2);
        assert_eq!(f.insert_word(0, 0b111, |_| 1), 0b111);
        assert_eq!(f.repr(), FrontierRepr::Dense);
        assert_eq!(f.len(), 3);
        assert_eq!(f.edges(), 3);
        assert_eq!(f.iter().collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn lookahead_walk_matches_iter_and_sees_ahead() {
        let mut f = Frontier::with_sparse_cap(512, 512);
        for &v in &[9u32, 300, 5, 130, 64] {
            f.insert(v, 1);
        }
        let mut far_seen = Vec::new();
        let mut near_seen = Vec::new();
        let mut visited = Vec::new();
        f.for_each_with_lookahead(
            2,
            |v| far_seen.push(v),
            1,
            |v| near_seen.push(v),
            |v| visited.push(v),
        );
        assert_eq!(visited, f.iter().collect::<Vec<_>>());
        // Warm-up covers the first `far` entries, then one-ahead each.
        assert_eq!(far_seen, vec![9, 300, 5, 130, 64]);
        assert_eq!(near_seen, vec![300, 5, 130, 64]);
        // Dense path: same visit order, no prefetch callbacks.
        f.to_dense();
        let mut dense_visited = Vec::new();
        f.for_each_with_lookahead(
            2,
            |_| panic!("no prefetch on the dense path"),
            1,
            |_| panic!("no prefetch on the dense path"),
            |v| dense_visited.push(v),
        );
        assert_eq!(dense_visited, vec![5, 9, 64, 130, 300]);
    }

    #[test]
    fn lowering_the_cap_converts_in_place() {
        let mut f = Frontier::with_sparse_cap(256, 256);
        for v in 0..10u32 {
            f.insert(v, 1);
        }
        assert!(f.is_sparse());
        f.set_sparse_cap(4);
        assert_eq!(f.repr(), FrontierRepr::Dense);
        assert_eq!(f.len(), 10);
        // Raising it back does not resurrect the list implicitly...
        f.set_sparse_cap(256);
        assert_eq!(f.repr(), FrontierRepr::Dense);
        // ...but an explicit conversion does.
        f.to_sparse();
        assert_eq!(f.sparse_verts().unwrap().len(), 10);
    }
}
