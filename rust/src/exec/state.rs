//! The search state every BFS engine operates on.
//!
//! On the U280 this state lives in double-pump BRAM/URAM: the current
//! and next frontiers (bitmap + frontier FIFO, see
//! [`Frontier`]), one bit per vertex for the visited map, plus the
//! level array in the PEs' local memory. A new search does not
//! reallocate any of it — the hardware simply clears the BRAMs — and
//! the software engines mirror that: [`SearchState::reset_for_root`]
//! zeroes the bitmaps and refills the level array in place (sparse
//! frontiers clear only the words they touched, via
//! [`crate::util::Bitset::clear_words_touched`]), which is what makes
//! multi-root batches cheap (see [`crate::bfs::batch::BatchDriver`]).

use super::frontier::Frontier;
use crate::bfs::INF;
use crate::graph::VertexId;
use crate::util::Bitset;

/// Frontiers + visited map + level array + the driver's per-iteration
/// signals.
///
/// Engines read `current`/`visited` and stage discoveries into `next`
/// (via [`Frontier::insert`], which accumulates the scheduler's
/// frontier-edges signal at insert time), `visited` and `levels` during
/// [`step`](super::BfsEngine::step); the shared driver swaps the
/// frontiers and rolls the scheduler signals forward between
/// iterations — no rescans.
#[derive(Clone, Debug)]
pub struct SearchState {
    /// Current frontier (vertices discovered last iteration).
    pub current: Frontier,
    /// Next frontier (vertices discovered this iteration).
    pub next: Frontier,
    /// Visited map.
    pub visited: Bitset,
    /// Per-vertex BFS level; `INF` when unreached.
    pub levels: Vec<u32>,
    /// Vertices in the current frontier (mirror of `current.len()`).
    pub frontier_size: u64,
    /// Sum of out-degrees of the current frontier (the scheduler's
    /// push→pull switching signal; mirror of `current.edges()`).
    pub frontier_edges: u64,
    /// Vertices visited so far (root included).
    pub visited_count: u64,
    /// Graph500 traversed-edge count so far: sum of out-degrees of the
    /// visited vertices, accumulated as frontiers retire (free with
    /// insert-time degree tracking — no end-of-run degree rescan).
    pub traversed_edges: u64,
    /// Iteration index of the iteration about to run (0-based).
    pub bfs_level: u32,
}

impl SearchState {
    /// Fresh all-clear state for an `n`-vertex graph. Call
    /// [`reset_for_root`](Self::reset_for_root) before driving a search.
    pub fn new(n: usize) -> Self {
        Self {
            current: Frontier::new(n),
            next: Frontier::new(n),
            visited: Bitset::new(n),
            levels: vec![INF; n],
            frontier_size: 0,
            frontier_edges: 0,
            visited_count: 0,
            traversed_edges: 0,
            bfs_level: 0,
        }
    }

    /// Number of vertices this state is sized for.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.levels.len()
    }

    /// In-place reset for a new search from `root` — the BRAM-clear
    /// pattern: no allocation, just zeroing (targeted word clears for
    /// frontiers that stayed sparse). `root_degree` seeds the
    /// scheduler's frontier-edges signal and the traversed-edge total.
    pub fn reset_for_root(&mut self, root: VertexId, root_degree: u64) {
        assert!(
            (root as usize) < self.num_vertices(),
            "root {root} out of range for {}-vertex state",
            self.num_vertices()
        );
        self.current.clear();
        self.next.clear();
        self.visited.clear_all();
        self.levels.iter_mut().for_each(|l| *l = INF);
        self.current.insert(root, root_degree);
        self.visited.set(root as usize);
        self.levels[root as usize] = 0;
        self.frontier_size = 1;
        self.frontier_edges = root_degree;
        self.visited_count = 1;
        self.traversed_edges = root_degree;
        self.bfs_level = 0;
    }

    /// End-of-iteration bookkeeping shared by every engine: retire the
    /// finished frontier into the traversed-edge total, swap the
    /// frontiers, clear the (new) next frontier, and roll the driver
    /// signals forward. `newly` is the number of vertices discovered by
    /// the iteration that just ran (engines count their own inserts;
    /// it must equal the staged frontier's population). The
    /// frontier-edges signal comes straight from the staged frontier's
    /// insert-time degree sum — nothing is rescanned.
    pub fn finish_iteration(&mut self, newly: u64) {
        debug_assert_eq!(
            newly,
            self.next.len(),
            "engine-reported discovery count diverges from staged frontier"
        );
        // The staged frontier is authoritative for the driver signals;
        // `newly` is cross-checked above but an engine whose self-count
        // drifts (e.g. a device kernel's reduction) cannot corrupt the
        // loop or the tracked totals.
        let staged = self.next.len();
        self.traversed_edges += self.next.edges();
        std::mem::swap(&mut self.current, &mut self.next);
        self.next.clear();
        self.frontier_size = staged;
        self.frontier_edges = self.current.edges();
        self.visited_count += staged;
        self.bfs_level += 1;
    }

    /// Vertices reached so far (root included) — tracked, not
    /// re-popcounted.
    pub fn reached(&self) -> usize {
        self.visited_count as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_clears_previous_search_in_place() {
        let mut s = SearchState::new(100);
        s.reset_for_root(3, 7);
        // Simulate some progress.
        s.visited.set(10);
        s.next.insert(10, 4);
        s.levels[10] = 1;
        s.finish_iteration(1);
        assert_eq!(s.frontier_size, 1);
        assert_eq!(s.frontier_edges, 4);
        assert_eq!(s.visited_count, 2);
        assert_eq!(s.traversed_edges, 11);
        assert_eq!(s.bfs_level, 1);
        // Reset for a different root: everything back to a fresh search.
        s.reset_for_root(42, 5);
        assert_eq!(s.visited.count_ones(), 1);
        assert!(s.visited.get(42));
        assert!(s.current.contains(42) && !s.current.contains(10));
        assert!(s.next.is_empty() && s.next.bits().none());
        assert_eq!(s.levels[42], 0);
        assert!(s.levels.iter().enumerate().all(|(v, &l)| v == 42 || l == INF));
        assert_eq!(s.frontier_size, 1);
        assert_eq!(s.frontier_edges, 5);
        assert_eq!(s.visited_count, 1);
        assert_eq!(s.traversed_edges, 5);
        assert_eq!(s.bfs_level, 0);
    }

    #[test]
    fn finish_iteration_swaps_and_clears_next() {
        let mut s = SearchState::new(10);
        s.reset_for_root(0, 2);
        s.next.insert(4, 3);
        s.finish_iteration(1);
        assert!(s.current.contains(4) && !s.current.contains(0));
        assert!(s.next.is_empty() && s.next.bits().none());
        assert_eq!(s.frontier_size, 1);
        assert_eq!(s.frontier_edges, 3);
        // Root degree + retired frontier degree.
        assert_eq!(s.traversed_edges, 5);
    }

    #[test]
    fn traversed_edges_accumulates_over_retired_frontiers() {
        let mut s = SearchState::new(16);
        s.reset_for_root(0, 2);
        s.next.insert(1, 3);
        s.next.insert(2, 4);
        s.finish_iteration(2);
        s.next.insert(3, 5);
        s.finish_iteration(1);
        s.finish_iteration(0);
        assert_eq!(s.traversed_edges, 2 + 3 + 4 + 5);
        assert_eq!(s.reached(), 4);
    }

    #[test]
    #[should_panic]
    fn oversized_root_is_rejected() {
        let mut s = SearchState::new(4);
        s.reset_for_root(4, 0);
    }
}
