//! The search state every BFS engine operates on.
//!
//! On the U280 this state lives in double-pump BRAM/URAM: one bit per
//! vertex for the current frontier, next frontier and visited map, plus
//! the level array in the PEs' local memory. A new search does not
//! reallocate any of it — the hardware simply clears the BRAMs — and
//! the software engines mirror that: [`SearchState::reset_for_root`]
//! zeroes the bitmaps and refills the level array in place, which is
//! what makes multi-root batches cheap (see
//! [`crate::bfs::batch::BatchDriver`]).

use crate::bfs::INF;
use crate::graph::VertexId;
use crate::util::Bitset;

/// Bitmaps + level array + the driver's per-iteration signals.
///
/// Engines read `current`/`visited` and stage discoveries into `next`,
/// `visited` and `levels` during [`step`](super::BfsEngine::step); the
/// shared driver swaps the frontiers and maintains the scheduler
/// signals between iterations.
#[derive(Clone, Debug)]
pub struct SearchState {
    /// Current-frontier bitmap (vertices discovered last iteration).
    pub current: Bitset,
    /// Next-frontier bitmap (vertices discovered this iteration).
    pub next: Bitset,
    /// Visited map.
    pub visited: Bitset,
    /// Per-vertex BFS level; `INF` when unreached.
    pub levels: Vec<u32>,
    /// Vertices in the current frontier.
    pub frontier_size: u64,
    /// Sum of out-degrees of the current frontier (the scheduler's
    /// push→pull switching signal).
    pub frontier_edges: u64,
    /// Vertices visited so far (root included).
    pub visited_count: u64,
    /// Iteration index of the iteration about to run (0-based).
    pub bfs_level: u32,
}

impl SearchState {
    /// Fresh all-clear state for an `n`-vertex graph. Call
    /// [`reset_for_root`](Self::reset_for_root) before driving a search.
    pub fn new(n: usize) -> Self {
        Self {
            current: Bitset::new(n),
            next: Bitset::new(n),
            visited: Bitset::new(n),
            levels: vec![INF; n],
            frontier_size: 0,
            frontier_edges: 0,
            visited_count: 0,
            bfs_level: 0,
        }
    }

    /// Number of vertices this state is sized for.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.levels.len()
    }

    /// In-place reset for a new search from `root` — the BRAM-clear
    /// pattern: no allocation, just zeroing. `root_degree` seeds the
    /// scheduler's frontier-edges signal.
    pub fn reset_for_root(&mut self, root: VertexId, root_degree: u64) {
        assert!(
            (root as usize) < self.num_vertices(),
            "root {root} out of range for {}-vertex state",
            self.num_vertices()
        );
        self.current.clear_all();
        self.next.clear_all();
        self.visited.clear_all();
        self.levels.iter_mut().for_each(|l| *l = INF);
        self.current.set(root as usize);
        self.visited.set(root as usize);
        self.levels[root as usize] = 0;
        self.frontier_size = 1;
        self.frontier_edges = root_degree;
        self.visited_count = 1;
        self.bfs_level = 0;
    }

    /// End-of-iteration bookkeeping shared by every engine: swap the
    /// frontiers, clear the (new) next bitmap, and roll the driver
    /// signals forward. `newly` is the number of vertices discovered by
    /// the iteration that just ran. `frontier_edges` must be updated by
    /// the caller afterwards (engines that scan in ascending order
    /// accumulate it inline; others recompute from the new frontier).
    pub fn finish_iteration(&mut self, newly: u64) {
        self.current.swap_with(&mut self.next);
        self.next.clear_all();
        self.frontier_size = newly;
        self.visited_count += newly;
        self.bfs_level += 1;
    }

    /// Vertices reached so far (root included).
    pub fn reached(&self) -> usize {
        self.visited.count_ones()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_clears_previous_search_in_place() {
        let mut s = SearchState::new(100);
        s.reset_for_root(3, 7);
        // Simulate some progress.
        s.visited.set(10);
        s.next.set(10);
        s.levels[10] = 1;
        s.finish_iteration(1);
        assert_eq!(s.frontier_size, 1);
        assert_eq!(s.visited_count, 2);
        assert_eq!(s.bfs_level, 1);
        // Reset for a different root: everything back to a fresh search.
        s.reset_for_root(42, 5);
        assert_eq!(s.visited.count_ones(), 1);
        assert!(s.visited.get(42));
        assert!(s.current.get(42) && !s.current.get(10));
        assert!(s.next.none());
        assert_eq!(s.levels[42], 0);
        assert!(s.levels.iter().enumerate().all(|(v, &l)| v == 42 || l == INF));
        assert_eq!(s.frontier_size, 1);
        assert_eq!(s.frontier_edges, 5);
        assert_eq!(s.visited_count, 1);
        assert_eq!(s.bfs_level, 0);
    }

    #[test]
    fn finish_iteration_swaps_and_clears_next() {
        let mut s = SearchState::new(10);
        s.reset_for_root(0, 2);
        s.next.set(4);
        s.finish_iteration(1);
        assert!(s.current.get(4) && !s.current.get(0));
        assert!(s.next.none());
        assert_eq!(s.frontier_size, 1);
    }

    #[test]
    #[should_panic]
    fn oversized_root_is_rejected() {
        let mut s = SearchState::new(4);
        s.reset_for_root(4, 0);
    }
}
