//! Bounded FIFO used between crossbar ports (the resource the paper
//! counts: a full 64×64 crossbar needs 4096 of these and "consumes more
//! than half of the LUTs in the U280").

use std::collections::VecDeque;

/// A bounded FIFO carrying routed vertex messages.
#[derive(Clone, Debug)]
pub struct Fifo<T> {
    buf: VecDeque<T>,
    /// Capacity in entries (paper example uses depth 16).
    pub depth: usize,
    /// Pushes rejected because the FIFO was full (backpressure events).
    pub backpressure: u64,
    /// High-water mark.
    pub max_occupancy: usize,
}

impl<T> Fifo<T> {
    /// FIFO of the given depth.
    pub fn new(depth: usize) -> Self {
        Self {
            buf: VecDeque::with_capacity(depth),
            depth,
            backpressure: 0,
            max_occupancy: 0,
        }
    }

    /// Try to enqueue; false (and a backpressure count) when full.
    pub fn push(&mut self, item: T) -> bool {
        if self.buf.len() >= self.depth {
            self.backpressure += 1;
            return false;
        }
        self.buf.push_back(item);
        self.max_occupancy = self.max_occupancy.max(self.buf.len());
        true
    }

    /// Dequeue the oldest entry.
    pub fn pop(&mut self) -> Option<T> {
        self.buf.pop_front()
    }

    /// The oldest entry without dequeuing it (the head a cycle-stepped
    /// router inspects before claiming an output port).
    pub fn peek(&self) -> Option<&T> {
        self.buf.front()
    }

    /// Current occupancy.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True when full.
    pub fn is_full(&self) -> bool {
        self.buf.len() >= self.depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            assert!(f.push(i));
        }
        assert!(f.is_full());
        assert_eq!(f.pop(), Some(0));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn backpressure_counted_when_full() {
        let mut f = Fifo::new(2);
        assert!(f.push(1));
        assert!(f.push(2));
        assert!(!f.push(3));
        assert!(!f.push(4));
        assert_eq!(f.backpressure, 2);
        assert_eq!(f.max_occupancy, 2);
    }

    #[test]
    fn empty_pop_is_none() {
        let mut f: Fifo<u32> = Fifo::new(1);
        assert!(f.is_empty());
        assert_eq!(f.pop(), None);
    }
}
