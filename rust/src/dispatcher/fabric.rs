//! Cycle-stepped runtime face of the vertex dispatcher (paper §IV-D).
//!
//! [`crate::dispatcher::FullCrossbar`] and
//! [`crate::dispatcher::MultiLayerCrossbar`] describe the *static*
//! design — routing function, FIFO count, hop count — for the resource
//! and analytic models. [`DispatcherFabric`] is the structure the cycle
//! simulator actually ticks: one rank of **bounded link FIFOs per
//! layer**, one rank per factor of `N = C₁ × … × C_k` (a full crossbar
//! is the single-layer `[N]` factorization). Per cycle:
//!
//! * each layer-`i` output port accepts at most `link_width` messages
//!   (Eq 1 sizes every link at two vertices per PE per cycle — the
//!   double-pump BRAM ingest rate; `link_width = 1` is the strict
//!   one-message-per-output-port-per-layer arbitration);
//! * a message whose output port is already at width this cycle is a
//!   **conflict** — it stays queued, and because links are FIFOs it
//!   also blocks everything behind it (head-of-line blocking, the loss
//!   mechanism that bends the Fig 10 PE-scaling curve);
//! * a message whose downstream FIFO is full is a **stall** — bounded
//!   queues back-pressure upstream instead of buffering infinitely, all
//!   the way to [`inject`](DispatcherFabric::inject)ion, whose rejects
//!   the PG's edge-beat stream must absorb by stalling its HBM port
//!   (see [`crate::sim::cycle`]).
//!
//! Total queued messages are bounded by construction: every message
//! lives in some layer's depth-bounded link FIFO, so
//! `total_queued() <= capacity()` always (the fabric debug-asserts it
//! each cycle). Hop latency is emergent — a message traverses one layer
//! rank per cycle, so the k-layer latency the static model reports as
//! [`hops`](crate::dispatcher::Dispatcher::hops) falls out of the
//! stepping rather than being charged as a flat delay.

use super::fifo::Fifo;
use crate::graph::VertexId;
use std::collections::VecDeque;

/// A routed vertex message: `vid` selects the destination PE
/// (`VID % N`), `child` carries the vertex a pull-mode parent check may
/// activate (`child == vid` in push mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VertexMsg {
    /// Vertex id the dispatcher routes by.
    pub vid: VertexId,
    /// Pull mode: the unvisited child whose parent `vid` is checked.
    pub child: VertexId,
}

/// Measured dispatcher behaviour over an observation window (one
/// iteration for [`crate::exec::StepStats`], a whole run once the
/// driver has [`merge`](DispatcherStats::merge)d the iterations).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DispatcherStats {
    /// Messages delivered out of the final layer into the PE FIFOs.
    pub delivered: u64,
    /// Head-of-queue messages that lost output-port arbitration (the
    /// port was already at `link_width` this cycle) — at injection
    /// into layer 0 or between ranks.
    pub conflicts: u64,
    /// Head-of-queue messages blocked by a full downstream link FIFO.
    pub stalls: u64,
    /// Injection attempts rejected by a full layer-0 entry FIFO — each
    /// one stalls the edge-beat stream that offered the message.
    pub inject_stalls: u64,
    /// Sum over observed cycles of the total queued messages
    /// (occupancy integral; divide by `cycles` for the mean).
    pub occupancy_sum: u64,
    /// High-water mark of total queued messages.
    pub max_occupancy: usize,
    /// Cycles observed.
    pub cycles: u64,
}

impl DispatcherStats {
    /// Mean queued messages per observed cycle.
    pub fn avg_occupancy(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.cycles as f64
        }
    }

    /// Fold another observation window into this one.
    pub fn merge(&mut self, other: &DispatcherStats) {
        self.delivered += other.delivered;
        self.conflicts += other.conflicts;
        self.stalls += other.stalls;
        self.inject_stalls += other.inject_stalls;
        self.occupancy_sum += other.occupancy_sum;
        self.max_occupancy = self.max_occupancy.max(other.max_occupancy);
        self.cycles += other.cycles;
    }
}

/// The cycle-stepped dispatcher: `k` ranks of `N` bounded link FIFOs.
///
/// `stages[i][lane]` holds messages that have traversed layers `0..=i`;
/// a lane's index agrees with the message's `vid` in mixed-radix digits
/// `0..=i` (radices `C₁..C_{i+1}`), so after the last rank the lane
/// *is* the destination PE and `stages[k-1]` doubles as the per-PE
/// input FIFOs the PEs' P2 stage drains.
pub struct DispatcherFabric {
    /// Layer radices (product = N). A full crossbar is `[N]`.
    factors: Vec<usize>,
    /// `lower[i]` = product of `factors[..i]` (mixed-radix place value).
    lower: Vec<usize>,
    n: usize,
    link_width: u32,
    fifo_depth: usize,
    stages: Vec<Vec<Fifo<VertexMsg>>>,
    /// Layer-0 (injection) output-port budget used this cycle.
    inject_used: Vec<u32>,
    /// Scratch per-port budget for internal layer moves.
    scratch_used: Vec<u32>,
    /// Per-layer round-robin arbitration offset.
    rr: Vec<usize>,
    /// Measured behaviour.
    pub stats: DispatcherStats,
}

impl DispatcherFabric {
    /// Fabric over a factorization of N with the given link FIFO depth
    /// and per-port link width (messages per output port per layer per
    /// cycle).
    pub fn new(factors: Vec<usize>, fifo_depth: usize, link_width: u32) -> Self {
        assert!(!factors.is_empty(), "at least one layer");
        assert!(factors.iter().all(|&c| c >= 1), "radices must be >= 1");
        assert!(fifo_depth >= 1 && link_width >= 1);
        let n: usize = factors.iter().product();
        let mut lower = Vec::with_capacity(factors.len());
        let mut acc = 1usize;
        for &c in &factors {
            lower.push(acc);
            acc *= c;
        }
        let k = factors.len();
        let stages = (0..k)
            .map(|_| (0..n).map(|_| Fifo::new(fifo_depth)).collect())
            .collect();
        Self {
            factors,
            lower,
            n,
            link_width,
            fifo_depth,
            stages,
            inject_used: vec![0; n],
            scratch_used: vec![0; n],
            rr: vec![0; k],
            stats: DispatcherStats::default(),
        }
    }

    /// Port count N.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Layers a message traverses (== the static model's hop count).
    pub fn hops(&self) -> usize {
        self.factors.len()
    }

    /// Mixed-radix digit `i` of a vertex id.
    #[inline]
    fn digit(&self, vid: VertexId, i: usize) -> usize {
        (vid as usize / self.lower[i]) % self.factors[i]
    }

    /// Output lane of layer `i` for a message currently in `lane`:
    /// digit `i` of the lane is replaced by the vid's digit `i` (the
    /// message stays inside its layer-`i` small crossbar).
    #[inline]
    fn out_lane(&self, lane: usize, vid: VertexId, i: usize) -> usize {
        let old = (lane / self.lower[i]) % self.factors[i];
        lane - old * self.lower[i] + self.digit(vid, i) * self.lower[i]
    }

    /// Start a new cycle: sample occupancy and reset the injection
    /// port budgets.
    pub fn begin_cycle(&mut self) {
        self.stats.cycles += 1;
        let queued = self.total_queued();
        debug_assert!(
            queued <= self.capacity(),
            "fabric occupancy {queued} exceeds total link FIFO capacity {}",
            self.capacity()
        );
        self.stats.occupancy_sum += queued as u64;
        self.stats.max_occupancy = self.stats.max_occupancy.max(queued);
        self.inject_used.fill(0);
    }

    /// Advance the internal ranks: for each layer boundary (from the
    /// output side back, so a message moves one rank per cycle), each
    /// input lane forwards up to `link_width` head messages, subject to
    /// the output port's `link_width` budget and the downstream FIFO's
    /// space. Blocked heads stay queued (FIFO links: head-of-line).
    pub fn tick(&mut self) {
        let k = self.factors.len();
        if k < 2 {
            return; // single layer: injection routes straight into the PE FIFOs
        }
        for i in (0..k - 1).rev() {
            // Move stage i -> stage i+1 through layer i+1's crossbars.
            self.scratch_used.fill(0);
            let rr = self.rr[i];
            for off in 0..self.n {
                let lane = (rr + off) % self.n;
                let mut sent = 0u32;
                while sent < self.link_width {
                    let Some(vid) = self.stages[i][lane].peek().map(|m| m.vid) else {
                        break;
                    };
                    let out = self.out_lane(lane, vid, i + 1);
                    if self.scratch_used[out] >= self.link_width {
                        self.stats.conflicts += 1;
                        break;
                    }
                    if self.stages[i + 1][out].is_full() {
                        self.stats.stalls += 1;
                        break;
                    }
                    let msg = self.stages[i][lane].pop().expect("peeked head");
                    let pushed = self.stages[i + 1][out].push(msg);
                    debug_assert!(pushed, "checked for space above");
                    self.scratch_used[out] += 1;
                    sent += 1;
                }
            }
            self.rr[i] = (rr + 1) % self.n;
        }
    }

    /// Offer a stream's staged messages to layer 0, in order, stopping
    /// at the first blocked one (the stream is a FIFO too). Each entry
    /// is `(src_lane, msg)` where `src_lane` is the lane of the PE
    /// whose subgraph stream produced the message. At most `budget`
    /// messages are accepted (the AXI width: one edge beat's worth per
    /// cycle), each subject to its layer-0 output port's `link_width`
    /// budget — shared with every other stream injecting this cycle —
    /// and the entry FIFO's space. Returns the number accepted; a
    /// shortfall means the offering stream must stall.
    pub fn inject(
        &mut self,
        staging: &mut VecDeque<(usize, VertexMsg)>,
        budget: u32,
    ) -> u32 {
        let mut accepted = 0u32;
        while accepted < budget {
            let Some(&(src_lane, msg)) = staging.front() else {
                break;
            };
            let out = self.out_lane(src_lane, msg.vid, 0);
            if self.inject_used[out] >= self.link_width {
                self.stats.conflicts += 1;
                break;
            }
            if self.stages[0][out].is_full() {
                self.stats.inject_stalls += 1;
                break;
            }
            staging.pop_front();
            let pushed = self.stages[0][out].push(msg);
            debug_assert!(pushed, "checked for space above");
            self.inject_used[out] += 1;
            accepted += 1;
        }
        accepted
    }

    /// Head of PE `lane`'s input FIFO (the final rank), if any.
    pub fn peek_output(&self, lane: usize) -> Option<&VertexMsg> {
        self.stages[self.factors.len() - 1][lane].peek()
    }

    /// Pop PE `lane`'s input FIFO (call only after
    /// [`peek_output`](Self::peek_output) and a successful BRAM port
    /// claim).
    pub fn pop_output(&mut self, lane: usize) -> Option<VertexMsg> {
        let msg = self.stages[self.factors.len() - 1][lane].pop();
        if msg.is_some() {
            self.stats.delivered += 1;
        }
        msg
    }

    /// Messages queued anywhere in the fabric.
    pub fn total_queued(&self) -> usize {
        self.stages
            .iter()
            .map(|rank| rank.iter().map(Fifo::len).sum::<usize>())
            .sum()
    }

    /// Σ layer FIFO capacities — the hard bound on
    /// [`total_queued`](Self::total_queued).
    pub fn capacity(&self) -> usize {
        self.stages.len() * self.n * self.fifo_depth
    }

    /// True when no message is queued in any rank.
    pub fn is_empty(&self) -> bool {
        self.total_queued() == 0
    }

    /// Lower bound on the cycles until the fabric can next change
    /// externally observable state on its own: `Some(1)` while any
    /// message is queued (it moves, conflicts, or stalls next tick),
    /// `None` when empty — an empty fabric only changes state when
    /// something is injected.
    pub fn next_event_in(&self) -> Option<u64> {
        (!self.is_empty()).then_some(1)
    }

    /// Bulk-advance `k` cycles of an **empty** fabric, bit-identical to
    /// `k` repetitions of [`begin_cycle`](Self::begin_cycle) +
    /// [`tick`](Self::tick) with nothing queued: the occupancy integral
    /// gains `k` zero samples and each layer boundary's round-robin
    /// offset rotates once per skipped cycle (the tick rotates it
    /// unconditionally, queued or not).
    pub fn advance(&mut self, k: u64) {
        debug_assert!(self.is_empty(), "advance() on a non-empty fabric");
        self.stats.cycles += k;
        let kk = (k % self.n as u64) as usize;
        for i in 0..self.factors.len().saturating_sub(1) {
            self.rr[i] = (self.rr[i] + kk) % self.n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(vid: u32) -> VertexMsg {
        VertexMsg { vid, child: vid }
    }

    fn drain_all(f: &mut DispatcherFabric, limit: u32) -> Vec<(usize, VertexMsg)> {
        let mut out = Vec::new();
        for _ in 0..limit {
            f.begin_cycle();
            for lane in 0..f.n() {
                while f.peek_output(lane).is_some() {
                    out.push((lane, f.pop_output(lane).unwrap()));
                }
            }
            f.tick();
            if f.is_empty() {
                break;
            }
        }
        out
    }

    #[test]
    fn routes_every_vid_to_vid_mod_n() {
        for factors in [vec![16], vec![4, 4], vec![2, 2, 2, 2], vec![4, 2, 2]] {
            let mut f = DispatcherFabric::new(factors.clone(), 64, 2);
            let mut staging: VecDeque<(usize, VertexMsg)> =
                (0..64u32).map(|v| (3usize, msg(v))).collect();
            let mut cycles = 0;
            while !staging.is_empty() {
                f.begin_cycle();
                f.inject(&mut staging, 8);
                // Drain outputs so the fabric never back-pressures.
                for lane in 0..f.n() {
                    while f.pop_output(lane).is_some() {}
                }
                f.tick();
                cycles += 1;
                assert!(cycles < 1000);
            }
            let delivered = drain_all(&mut f, 1000);
            // Every injected message was or will be delivered at vid % 16.
            for (lane, m) in delivered {
                assert_eq!(lane, m.vid as usize % 16, "factors {factors:?}");
            }
            assert!(f.is_empty());
        }
    }

    #[test]
    fn bounded_occupancy_and_backpressure() {
        // Depth-2 FIFOs, width 1: flood one hot destination.
        let mut f = DispatcherFabric::new(vec![4, 4], 2, 1);
        let mut staging: VecDeque<(usize, VertexMsg)> =
            (0..64).map(|_| (0usize, msg(5))).collect();
        for _ in 0..10 {
            f.begin_cycle();
            f.inject(&mut staging, 8);
            f.tick();
        }
        assert!(f.total_queued() <= f.capacity());
        assert!(!staging.is_empty(), "bounded FIFOs must refuse the flood");
        assert!(f.stats.conflicts > 0, "width-1 hot port must conflict");
        assert!(
            f.stats.stalls + f.stats.inject_stalls > 0,
            "depth-2 FIFOs must fill and stall"
        );
        // Nothing is lost: staged + queued + delivered == 64.
        let delivered = drain_all(&mut f, 10_000);
        assert_eq!(
            staging.len() + delivered.len(),
            64,
            "messages must never be dropped"
        );
        for (lane, m) in delivered {
            assert_eq!(lane, 5);
            assert_eq!(m.vid, 5);
        }
    }

    #[test]
    fn hot_port_conflicts_are_counted() {
        // Two streams, both aimed at PE 0 through the same layer-0
        // port group: width 1 admits one per cycle, the other loses
        // the port arbitration.
        let mut f = DispatcherFabric::new(vec![4, 4], 16, 1);
        let mut a: VecDeque<(usize, VertexMsg)> = (0..8).map(|_| (0usize, msg(0))).collect();
        let mut b: VecDeque<(usize, VertexMsg)> = (0..8).map(|_| (1usize, msg(4))).collect();
        f.begin_cycle();
        // Both route to layer-0 port 0 of crossbar 0 (digit0 of 0 and 4
        // is 0; lanes 0 and 1 share lower digits' group).
        let got_a = f.inject(&mut a, 4);
        let got_b = f.inject(&mut b, 4);
        assert_eq!(got_a, 1, "width-1 port admits one");
        assert_eq!(got_b, 0, "port budget is shared across streams");
        assert!(f.stats.conflicts > 0, "arbitration losses are conflicts");
        assert_eq!(f.stats.inject_stalls, 0, "no FIFO was full");
    }

    #[test]
    fn latency_is_one_cycle_per_layer() {
        let mut f = DispatcherFabric::new(vec![4, 4], 16, 2);
        let mut staging: VecDeque<(usize, VertexMsg)> = VecDeque::from([(0usize, msg(7))]);
        f.begin_cycle();
        assert_eq!(f.inject(&mut staging, 4), 1);
        // After injection the message sits in rank 0; one tick moves it
        // to rank 1 (the PE FIFO).
        assert!(f.peek_output(7).is_none());
        f.tick();
        assert_eq!(f.peek_output(7).map(|m| m.vid), Some(7));
        assert_eq!(f.pop_output(7).unwrap().vid, 7);
        assert!(f.is_empty());
        assert_eq!(f.stats.delivered, 1);
    }

    #[test]
    fn single_layer_full_crossbar_delivers_in_one_hop() {
        let mut f = DispatcherFabric::new(vec![8], 16, 2);
        let mut staging: VecDeque<(usize, VertexMsg)> =
            VecDeque::from([(2usize, msg(11)), (2usize, msg(3))]);
        f.begin_cycle();
        assert_eq!(f.inject(&mut staging, 8), 2);
        assert_eq!(f.pop_output(11 % 8).unwrap().vid, 11);
        assert_eq!(f.pop_output(3).unwrap().vid, 3);
        assert_eq!(f.hops(), 1);
    }

    #[test]
    fn occupancy_stats_accumulate() {
        let mut f = DispatcherFabric::new(vec![4], 16, 2);
        let mut staging: VecDeque<(usize, VertexMsg)> =
            (0..6u32).map(|v| (0usize, msg(v))).collect();
        f.begin_cycle();
        f.inject(&mut staging, 2);
        f.begin_cycle(); // samples the 2 queued messages
        assert!(f.stats.occupancy_sum >= 2);
        assert!(f.stats.max_occupancy >= 2);
        assert!(f.stats.avg_occupancy() > 0.0);
        let mut merged = DispatcherStats::default();
        merged.merge(&f.stats);
        merged.merge(&f.stats);
        assert_eq!(merged.cycles, 2 * f.stats.cycles);
        assert_eq!(merged.max_occupancy, f.stats.max_occupancy);
    }
}
