//! Full N×N crossbar (paper Fig 6a): 1-hop routing, N² FIFOs.

use super::Dispatcher;

/// The naive full crossbar: every input port has a dedicated FIFO to
/// every output port.
#[derive(Clone, Copy, Debug)]
pub struct FullCrossbar {
    /// Number of ports (== PEs == subgraph streams).
    pub n: usize,
    /// FIFO depth per link (affects resources, not routing).
    pub fifo_depth: usize,
}

impl FullCrossbar {
    /// N×N crossbar with the paper's example FIFO depth (16).
    pub fn new(n: usize) -> Self {
        Self { n, fifo_depth: 16 }
    }
}

impl Dispatcher for FullCrossbar {
    fn route(&self, vid: u32) -> usize {
        (vid as usize) % self.n
    }

    fn fifo_count(&self) -> u64 {
        (self.n as u64) * (self.n as u64)
    }

    fn hops(&self) -> u32 {
        1
    }

    fn describe(&self) -> String {
        format!("full {}x{} crossbar ({} FIFOs)", self.n, self.n, self.fifo_count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_by_modulo() {
        let xb = FullCrossbar::new(16);
        assert_eq!(xb.route(0), 0);
        assert_eq!(xb.route(17), 1);
        assert_eq!(xb.route(31), 15);
    }

    #[test]
    fn fifo_count_is_n_squared() {
        assert_eq!(FullCrossbar::new(16).fifo_count(), 256);
        assert_eq!(FullCrossbar::new(32).fifo_count(), 1024);
        assert_eq!(FullCrossbar::new(64).fifo_count(), 4096);
    }

    #[test]
    fn single_hop() {
        assert_eq!(FullCrossbar::new(8).hops(), 1);
    }
}
