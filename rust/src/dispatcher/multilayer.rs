//! Multi-layer crossbar (paper §IV-D, Fig 6b): the resource-efficient
//! vertex dispatcher that makes 64 PEs fit on the U280.
//!
//! Factor `N = C₁ × C₂ × … × C_k`. Layer 1 uses `N/C₁` small `C₁×C₁`
//! crossbars and classifies vertices into `C₁` groups by `VID % C₁`;
//! layer i refines the classification to `C₁×…×Cᵢ` groups by
//! `VID % (C₁…Cᵢ)`; after layer k the `N` groups map 1:1 onto PEs. FIFO
//! cost is `Σ (N/Cᵢ)·Cᵢ²` versus the full crossbar's `N²`; the price is
//! `k`-hop latency, acceptable for throughput-critical BFS.

use super::Dispatcher;

/// A k-layer crossbar described by its factorization of N.
#[derive(Clone, Debug)]
pub struct MultiLayerCrossbar {
    /// Layer radices; their product is N.
    pub factors: Vec<usize>,
    /// FIFO depth per link.
    pub fifo_depth: usize,
}

impl MultiLayerCrossbar {
    /// Build from explicit factors (e.g. `[4, 4, 4]` for the paper's
    /// 64-PE configuration).
    pub fn new(factors: Vec<usize>) -> Self {
        assert!(!factors.is_empty());
        assert!(factors.iter().all(|&c| c >= 2), "radix must be >= 2");
        Self {
            factors,
            fifo_depth: 16,
        }
    }

    /// Factor N into radix-`c` layers, with one smaller remainder layer
    /// when N is not a pure power of c (e.g. 32 -> [4, 4, 2]). If N has
    /// no factor of c at all, this degenerates to a single N×N layer
    /// (i.e. a full crossbar).
    pub fn balanced(n: usize, c: usize) -> Self {
        assert!(c >= 2 && n >= 2);
        let mut layers = Vec::new();
        let mut rem = n;
        while rem % c == 0 && rem > 1 {
            layers.push(c);
            rem /= c;
        }
        if rem > 1 {
            layers.push(rem);
        }
        Self::new(layers)
    }

    /// Total port count N.
    pub fn n(&self) -> usize {
        self.factors.iter().product()
    }

    /// Number of small crossbars in layer `i`.
    pub fn crossbars_in_layer(&self, i: usize) -> usize {
        self.n() / self.factors[i]
    }

    /// The group index a vertex belongs to after traversing layer `i`
    /// (0-based): `VID % (C₁·…·C_{i+1})`.
    pub fn group_after_layer(&self, vid: u32, i: usize) -> usize {
        let modulus: usize = self.factors[..=i].iter().product();
        (vid as usize) % modulus
    }

    /// The output port of the layer-`i` crossbar a message selects:
    /// the refinement digit `(VID / (C₁·…·Cᵢ₋₁)) % Cᵢ`... routing in the
    /// paper is by residue: layer i sends to port `VID % Cᵢ` of the
    /// appropriate small crossbar; equivalently the digit of `VID` in the
    /// mixed-radix basis (C₁, …, C_k).
    pub fn digit(&self, vid: u32, i: usize) -> usize {
        let lower: usize = self.factors[..i].iter().product();
        ((vid as usize) / lower) % self.factors[i]
    }

    /// Simulate the layer traversal of a vertex and return the final PE.
    /// This mirrors Fig 6b: after layer i the message sits in group
    /// `VID % (C₁…Cᵢ)`; after the last layer that group *is* the PE id.
    pub fn simulate_route(&self, vid: u32) -> usize {
        let mut group = 0usize;
        let mut modulus = 1usize;
        for (i, &c) in self.factors.iter().enumerate() {
            // The layer refines the residue: group' = group + digit * modulus
            // where digit = (vid / modulus) % c  == digit(vid, i).
            group += self.digit(vid, i) * modulus;
            modulus *= c;
            debug_assert_eq!(group, self.group_after_layer(vid, i));
        }
        group
    }
}

impl Dispatcher for MultiLayerCrossbar {
    fn route(&self, vid: u32) -> usize {
        self.simulate_route(vid)
    }

    fn fifo_count(&self) -> u64 {
        self.factors
            .iter()
            .map(|&c| (self.n() / c) as u64 * (c as u64) * (c as u64))
            .sum()
    }

    fn hops(&self) -> u32 {
        self.factors.len() as u32
    }

    fn describe(&self) -> String {
        let layers: Vec<String> = self.factors.iter().map(|c| format!("{c}x{c}")).collect();
        format!(
            "{}-layer crossbar [{}] on N={} ({} FIFOs)",
            self.factors.len(),
            layers.join(", "),
            self.n(),
            self.fifo_count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatcher::crossbar::FullCrossbar;

    #[test]
    fn paper_16_example_two_layers_of_4() {
        let ml = MultiLayerCrossbar::new(vec![4, 4]);
        assert_eq!(ml.n(), 16);
        // Paper: two-layer consumes 2*4*4*4 = 128 FIFOs vs 256 full.
        assert_eq!(ml.fifo_count(), 128);
        assert_eq!(FullCrossbar::new(16).fifo_count(), 256);
        assert_eq!(ml.hops(), 2);
    }

    #[test]
    fn paper_64_config_three_layers_of_4() {
        let ml = MultiLayerCrossbar::new(vec![4, 4, 4]);
        assert_eq!(ml.n(), 64);
        // Paper §VI-B: 3 * 16 * 4 * 4 = 768 FIFOs (vs 4096 full).
        assert_eq!(ml.fifo_count(), 768);
        assert_eq!(ml.crossbars_in_layer(0), 16);
    }

    #[test]
    fn routing_equals_modulo_for_all_vids() {
        for factors in [vec![4, 4], vec![2, 2, 2, 2], vec![4, 2, 2], vec![8, 8]] {
            let ml = MultiLayerCrossbar::new(factors.clone());
            let n = ml.n();
            for vid in 0..(4 * n as u32) {
                assert_eq!(
                    ml.route(vid),
                    (vid as usize) % n,
                    "factors {factors:?} vid {vid}"
                );
            }
        }
    }

    #[test]
    fn balanced_factorization() {
        let ml = MultiLayerCrossbar::balanced(64, 4);
        assert_eq!(ml.factors, vec![4, 4, 4]);
        let ml2 = MultiLayerCrossbar::balanced(16, 2);
        assert_eq!(ml2.factors, vec![2, 2, 2, 2]);
    }

    #[test]
    fn multilayer_always_cheaper_than_full() {
        for (n, c) in [(16, 4), (64, 4), (64, 2), (256, 4)] {
            let ml = MultiLayerCrossbar::balanced(n, c);
            assert!(
                ml.fifo_count() < (n * n) as u64,
                "n={n} c={c}: {} !< {}",
                ml.fifo_count(),
                n * n
            );
        }
    }

    #[test]
    fn balanced_handles_remainders() {
        assert_eq!(MultiLayerCrossbar::balanced(32, 4).factors, vec![4, 4, 2]);
        // No factor of 5 in 12: degenerates to a single full layer.
        assert_eq!(MultiLayerCrossbar::balanced(12, 5).factors, vec![12]);
        // Routing still correct with a remainder layer.
        let ml = MultiLayerCrossbar::balanced(32, 4);
        for vid in 0..128u32 {
            assert_eq!(ml.route(vid), (vid as usize) % 32);
        }
    }
}
