//! Vertex dispatcher (paper §IV-D, Fig 6): gathers neighbor-list streams
//! from all PCs and scatters each vertex to the PE owning it
//! (`VID % N_pe`).
//!
//! Two interchangeable static designs:
//! * [`crossbar::FullCrossbar`] — the naive N×N design: 1-hop latency,
//!   N² FIFOs (unbuildable at N=64 on the U280).
//! * [`multilayer::MultiLayerCrossbar`] — the paper's contribution: factor
//!   N = C₁×…×C_k, route through k layers of small crossbars; FIFO count
//!   drops to Σ (N/Cᵢ)·Cᵢ², latency grows to k hops. Throughput-critical
//!   BFS tolerates the latency (§IV-D).
//!
//! Both describe routing/resource/latency *statically* for the
//! analytic and resource models. The cycle simulator instead ticks
//! [`fabric::DispatcherFabric`] — the runtime face of either design:
//! per-layer bounded link FIFOs, per-output-port arbitration, measured
//! [`fabric::DispatcherStats`] (conflicts, stalls, occupancy), and
//! back-pressure that propagates all the way into the HBM edge-beat
//! stream instead of buffering unboundedly.

pub mod fifo;
pub mod crossbar;
pub mod multilayer;
pub mod fabric;

pub use crossbar::FullCrossbar;
pub use fabric::{DispatcherFabric, DispatcherStats, VertexMsg};
pub use multilayer::MultiLayerCrossbar;

/// Routing contract shared by both crossbar designs.
pub trait Dispatcher {
    /// Destination PE for a vertex id (must equal `vid % n_pes`).
    fn route(&self, vid: u32) -> usize;
    /// Number of FIFOs the design instantiates (resource model input).
    fn fifo_count(&self) -> u64;
    /// Hops a message traverses (latency model input).
    fn hops(&self) -> u32;
    /// Human-readable description.
    fn describe(&self) -> String;
}
