//! Additional scheduling policies beyond the paper's hybrid scheduler —
//! design-exploration extensions referenced from DESIGN.md.
//!
//! * [`DegreeAware`] — the FPGA-HMC degree-aware heuristic of Zhang &
//!   Li [9] (paper reference): switch on the *edge* fraction touched
//!   rather than Beamer's two-threshold scheme.
//! * [`FrontierFraction`] — the simple |frontier|/|V| rule several FPGA
//!   BFS engines use (single threshold, cheap in hardware).
//! * [`ModeTrace`] — wraps any policy and records its decisions (used
//!   by reports and tests).

use super::ModePolicy;
use crate::bfs::Mode;

/// Degree-aware switching: go pull once the frontier's outgoing edges
/// exceed `theta` of all edges; return to push when the frontier
/// shrinks below the same fraction of vertices.
#[derive(Clone, Copy, Debug)]
pub struct DegreeAware {
    /// Edge-fraction threshold (typical: 0.03–0.10).
    pub theta: f64,
    state: Mode,
}

impl DegreeAware {
    /// New policy with threshold `theta`.
    pub fn new(theta: f64) -> Self {
        Self {
            theta,
            state: Mode::Push,
        }
    }
}

impl Default for DegreeAware {
    fn default() -> Self {
        Self::new(0.05)
    }
}

impl ModePolicy for DegreeAware {
    fn decide(
        &mut self,
        _bfs_level: u32,
        frontier_size: u64,
        frontier_edges: u64,
        _visited: u64,
        n: u64,
        m: u64,
    ) -> Mode {
        match self.state {
            Mode::Push => {
                if frontier_edges as f64 > self.theta * m as f64 {
                    self.state = Mode::Pull;
                }
            }
            Mode::Pull => {
                if (frontier_size as f64) < self.theta * n as f64 {
                    self.state = Mode::Push;
                }
            }
        }
        self.state
    }

    fn name(&self) -> String {
        format!("degree-aware(theta={})", self.theta)
    }
}

/// Single-threshold frontier-fraction rule: pull iff
/// `frontier_size > n / divisor`.
#[derive(Clone, Copy, Debug)]
pub struct FrontierFraction {
    /// Pull when the frontier exceeds |V| / divisor.
    pub divisor: f64,
}

impl Default for FrontierFraction {
    fn default() -> Self {
        Self { divisor: 50.0 }
    }
}

impl ModePolicy for FrontierFraction {
    fn decide(&mut self, _: u32, frontier_size: u64, _: u64, _: u64, n: u64, _: u64) -> Mode {
        if (frontier_size as f64) > n as f64 / self.divisor {
            Mode::Pull
        } else {
            Mode::Push
        }
    }

    fn name(&self) -> String {
        format!("frontier-fraction(1/{})", self.divisor)
    }
}

/// Decision recorder: delegates to an inner policy and keeps the trace.
pub struct ModeTrace<P: ModePolicy> {
    /// Wrapped policy.
    pub inner: P,
    /// Decisions in iteration order.
    pub trace: Vec<Mode>,
}

impl<P: ModePolicy> ModeTrace<P> {
    /// Wrap a policy.
    pub fn new(inner: P) -> Self {
        Self {
            inner,
            trace: Vec::new(),
        }
    }

    /// Count of (push, pull) decisions so far.
    pub fn counts(&self) -> (usize, usize) {
        let push = self.trace.iter().filter(|m| **m == Mode::Push).count();
        (push, self.trace.len() - push)
    }
}

impl<P: ModePolicy> ModePolicy for ModeTrace<P> {
    fn decide(
        &mut self,
        bfs_level: u32,
        frontier_size: u64,
        frontier_edges: u64,
        visited: u64,
        n: u64,
        m: u64,
    ) -> Mode {
        let d = self
            .inner
            .decide(bfs_level, frontier_size, frontier_edges, visited, n, m);
        self.trace.push(d);
        d
    }

    fn name(&self) -> String {
        format!("traced({})", self.inner.name())
    }

    fn repr(&self) -> super::ReprPolicy {
        self.inner.repr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_aware_switches_on_edge_fraction() {
        let mut p = DegreeAware::new(0.05);
        // 3% of edges: stay push.
        assert_eq!(p.decide(0, 10, 30, 10, 1000, 1000), Mode::Push);
        // 10% of edges: switch to pull.
        assert_eq!(p.decide(1, 50, 100, 60, 1000, 1000), Mode::Pull);
        // Small frontier: back to push.
        assert_eq!(p.decide(2, 10, 5, 900, 1000, 1000), Mode::Push);
    }

    #[test]
    fn frontier_fraction_is_stateless() {
        let mut p = FrontierFraction { divisor: 10.0 };
        assert_eq!(p.decide(0, 5, 0, 0, 100, 0), Mode::Push);
        assert_eq!(p.decide(1, 50, 0, 0, 100, 0), Mode::Pull);
        assert_eq!(p.decide(2, 5, 0, 0, 100, 0), Mode::Push);
    }

    #[test]
    fn trace_records_decisions() {
        let mut p = ModeTrace::new(FrontierFraction { divisor: 10.0 });
        p.decide(0, 5, 0, 0, 100, 0);
        p.decide(1, 50, 0, 0, 100, 0);
        assert_eq!(p.trace, vec![Mode::Push, Mode::Pull]);
        assert_eq!(p.counts(), (1, 1));
        assert!(p.name().starts_with("traced"));
    }

    #[test]
    fn policies_produce_correct_bfs() {
        use crate::bfs::bitmap::{run_bfs, BitmapEngine, TrafficConfig};
        use crate::bfs::reference;
        use crate::graph::{generators, Partitioning};
        let g = std::sync::Arc::new(generators::rmat_graph500(9, 8, 17));
        let root = reference::sample_roots(&g, 1, 17)[0];
        let truth = reference::bfs(&g, root);
        let part = Partitioning::new(4, 2);
        // Every extension policy, under every host datapath: the
        // default word-parallel/tiled path, the scalar oracle, and
        // tiles small enough to engage on a 512-vertex graph.
        let base = TrafficConfig::for_partitioning(part);
        for policy in [
            &mut DegreeAware::default() as &mut dyn ModePolicy,
            &mut FrontierFraction::default(),
        ] {
            let run = run_bfs(&g, part, root, policy);
            assert_eq!(run.levels, truth.levels, "{}", policy.name());
        }
        for cfg in [base, base.host_scalar(), base.with_push_tiling(Some(4))] {
            for policy in [
                &mut DegreeAware::default() as &mut dyn ModePolicy,
                &mut FrontierFraction::default(),
            ] {
                let run = BitmapEngine::new(g.clone(), part)
                    .with_config(cfg)
                    .run(root, policy);
                assert_eq!(run.levels, truth.levels, "{}", policy.name());
            }
        }
    }
}
