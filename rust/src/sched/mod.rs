//! The Scheduler (paper Fig 4): decides push vs pull for each iteration
//! and informs the PEs at iteration start.
//!
//! The paper uses push in the beginning/ending iterations and pull in the
//! mid-term ones (§II-A, Algorithm 2). [`Hybrid`] implements the
//! direction-optimizing heuristic of Beamer et al. [33] — the scheme the
//! paper's scheduler (and Gunrock's) follows: switch push→pull when the
//! frontier's outgoing edges exceed `1/alpha` of the unexplored edges, and
//! pull→push when the frontier shrinks below `|V|/beta` vertices.

pub mod policies;

pub use policies::{DegreeAware, FrontierFraction, ModeTrace};

use crate::bfs::Mode;
use crate::exec::frontier::{adaptive_sparse_cap, DEFAULT_SPARSE_DIVISOR};

/// How the scheduler represents each staged frontier — the second half
/// of its per-iteration decision. Beamer-style direction optimization
/// pairs the push/pull switch with a sparse-queue ↔ dense-bitmap
/// representation switch, so the threshold lives here, next to
/// `alpha`/`beta`, and the shared driver applies it to the next
/// frontier before every [`ModePolicy::decide`]d iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReprPolicy {
    /// Always the dense bitmap (the pre-refactor behaviour; the
    /// forced-dense axis of the differential tests and the dense-only
    /// baseline of `benches/perf_frontier.rs`).
    Dense,
    /// Always the sparse vertex list, whatever the frontier size.
    Sparse,
    /// Sparse while the frontier holds fewer than `|V| / divisor`
    /// vertices, dense beyond (the default; divisor
    /// [`DEFAULT_SPARSE_DIVISOR`]).
    Adaptive(u32),
}

impl Default for ReprPolicy {
    fn default() -> Self {
        ReprPolicy::Adaptive(DEFAULT_SPARSE_DIVISOR)
    }
}

impl ReprPolicy {
    /// Sparse-list capacity for an `n`-vertex graph: the staged
    /// frontier overflows to dense beyond this many vertices.
    pub fn sparse_cap(self, n: usize) -> usize {
        match self {
            ReprPolicy::Dense => 0,
            ReprPolicy::Sparse => n.max(1),
            ReprPolicy::Adaptive(divisor) => adaptive_sparse_cap(n, divisor),
        }
    }

    /// Short label for test/report names.
    pub fn label(self) -> String {
        match self {
            ReprPolicy::Dense => "dense".into(),
            ReprPolicy::Sparse => "sparse".into(),
            ReprPolicy::Adaptive(d) => format!("adaptive(1/{d})"),
        }
    }
}

/// Per-iteration mode decision.
pub trait ModePolicy {
    /// Decide the mode for the iteration about to run.
    ///
    /// * `bfs_level` — iteration index.
    /// * `frontier_size` — vertices in the current frontier.
    /// * `frontier_edges` — sum of out-degrees of the frontier.
    /// * `visited` — vertices visited so far.
    /// * `n`, `m` — |V|, |E| of the graph.
    fn decide(
        &mut self,
        bfs_level: u32,
        frontier_size: u64,
        frontier_edges: u64,
        visited: u64,
        n: u64,
        m: u64,
    ) -> Mode;

    /// Human-readable policy name for reports.
    fn name(&self) -> String;

    /// Representation policy for the frontiers this scheduler stages —
    /// direction and representation switch together. Defaults to the
    /// adaptive sparse/dense threshold; override to force an axis (see
    /// [`WithRepr`]).
    fn repr(&self) -> ReprPolicy {
        ReprPolicy::default()
    }
}

/// Wrap any policy with an explicit frontier-representation choice —
/// the forced-sparse / forced-dense axes of the differential tests and
/// benches. Mode decisions delegate unchanged; the wrapper's `repr`
/// *overrides* whatever the inner policy (e.g. [`Hybrid::repr`])
/// would report.
pub struct WithRepr<P: ModePolicy> {
    /// The wrapped direction policy.
    pub inner: P,
    /// The representation to force.
    pub repr: ReprPolicy,
}

impl<P: ModePolicy> ModePolicy for WithRepr<P> {
    fn decide(
        &mut self,
        bfs_level: u32,
        frontier_size: u64,
        frontier_edges: u64,
        visited: u64,
        n: u64,
        m: u64,
    ) -> Mode {
        self.inner
            .decide(bfs_level, frontier_size, frontier_edges, visited, n, m)
    }

    fn name(&self) -> String {
        format!("{}+{}", self.inner.name(), self.repr.label())
    }

    fn repr(&self) -> ReprPolicy {
        self.repr
    }
}

/// Always run the same mode (the Fig 8 push-only / pull-only baselines).
pub struct Fixed(pub Mode);

impl ModePolicy for Fixed {
    fn decide(&mut self, _: u32, _: u64, _: u64, _: u64, _: u64, _: u64) -> Mode {
        self.0
    }

    fn name(&self) -> String {
        format!("{}-only", self.0)
    }
}

/// Direction-optimizing hybrid scheduler.
#[derive(Clone, Copy, Debug)]
pub struct Hybrid {
    /// push→pull when `frontier_edges > unexplored_edges / alpha`.
    pub alpha: f64,
    /// pull→push when `frontier_size < n / beta`.
    pub beta: f64,
    /// Representation threshold for staged frontiers (the scheduler
    /// owns both halves of the per-iteration switch).
    pub repr: ReprPolicy,
    state: Mode,
}

impl Default for Hybrid {
    fn default() -> Self {
        // Beamer's published defaults.
        Self {
            alpha: 14.0,
            beta: 24.0,
            repr: ReprPolicy::default(),
            state: Mode::Push,
        }
    }
}

impl Hybrid {
    /// Hybrid policy with explicit thresholds.
    pub fn new(alpha: f64, beta: f64) -> Self {
        Self {
            alpha,
            beta,
            repr: ReprPolicy::default(),
            state: Mode::Push,
        }
    }

    /// Override the frontier-representation policy.
    pub fn with_repr(mut self, repr: ReprPolicy) -> Self {
        self.repr = repr;
        self
    }
}

impl ModePolicy for Hybrid {
    fn decide(
        &mut self,
        _bfs_level: u32,
        frontier_size: u64,
        frontier_edges: u64,
        visited: u64,
        n: u64,
        m: u64,
    ) -> Mode {
        match self.state {
            Mode::Push => {
                // Unexplored edges approximated as m minus edges of
                // visited vertices ~ m * (1 - visited/n) (cheap signal the
                // hardware scheduler can compute on the fly).
                let unexplored =
                    (m as f64 * (1.0 - visited as f64 / n.max(1) as f64)).max(1.0);
                if frontier_edges as f64 > unexplored / self.alpha {
                    self.state = Mode::Pull;
                }
            }
            Mode::Pull => {
                if (frontier_size as f64) < n as f64 / self.beta {
                    self.state = Mode::Push;
                }
            }
        }
        self.state
    }

    fn name(&self) -> String {
        format!("hybrid(a={},b={})", self.alpha, self.beta)
    }

    fn repr(&self) -> ReprPolicy {
        self.repr
    }
}

/// Scripted mode sequence (tests / ablations): iteration i runs `seq[i]`,
/// clamped to the last entry.
pub struct Scripted(pub Vec<Mode>);

impl ModePolicy for Scripted {
    fn decide(&mut self, bfs_level: u32, _: u64, _: u64, _: u64, _: u64, _: u64) -> Mode {
        let i = (bfs_level as usize).min(self.0.len().saturating_sub(1));
        self.0[i]
    }

    fn name(&self) -> String {
        "scripted".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_switches() {
        let mut p = Fixed(Mode::Pull);
        for i in 0..5 {
            assert_eq!(p.decide(i, 1, 1, 1, 100, 1000), Mode::Pull);
        }
    }

    #[test]
    fn hybrid_starts_push_switches_to_pull_and_back() {
        let mut p = Hybrid::default();
        // Tiny frontier: stays push.
        assert_eq!(p.decide(0, 1, 2, 1, 1000, 10000), Mode::Push);
        // Frontier edges explode past unexplored/alpha: go pull.
        assert_eq!(p.decide(1, 400, 9000, 400, 1000, 10000), Mode::Pull);
        // Large frontier: stays pull.
        assert_eq!(p.decide(2, 500, 500, 900, 1000, 10000), Mode::Pull);
        // Frontier collapses: back to push.
        assert_eq!(p.decide(3, 5, 10, 990, 1000, 10000), Mode::Push);
    }

    #[test]
    fn scripted_follows_sequence_and_clamps() {
        let mut p = Scripted(vec![Mode::Push, Mode::Pull]);
        assert_eq!(p.decide(0, 0, 0, 0, 1, 1), Mode::Push);
        assert_eq!(p.decide(1, 0, 0, 0, 1, 1), Mode::Pull);
        assert_eq!(p.decide(9, 0, 0, 0, 1, 1), Mode::Pull);
    }

    #[test]
    fn names_are_descriptive() {
        assert_eq!(Fixed(Mode::Push).name(), "push-only");
        assert!(Hybrid::default().name().starts_with("hybrid"));
        let forced = WithRepr {
            inner: Fixed(Mode::Push),
            repr: ReprPolicy::Sparse,
        };
        assert_eq!(forced.name(), "push-only+sparse");
    }

    #[test]
    fn repr_policy_caps_scale_with_n() {
        assert_eq!(ReprPolicy::Dense.sparse_cap(1 << 20), 0);
        assert_eq!(ReprPolicy::Sparse.sparse_cap(1 << 20), 1 << 20);
        // Default divisor: |V|/32 (with the small-graph floor).
        assert_eq!(ReprPolicy::default().sparse_cap(1 << 20), 1 << 15);
        assert_eq!(ReprPolicy::Adaptive(4).sparse_cap(1 << 20), 1 << 18);
        // Tiny graphs never get a zero adaptive cap.
        assert!(ReprPolicy::default().sparse_cap(10) >= 10);
    }

    #[test]
    fn with_repr_delegates_decisions_and_forces_repr() {
        let mut p = WithRepr {
            inner: Fixed(Mode::Pull),
            repr: ReprPolicy::Dense,
        };
        assert_eq!(p.decide(0, 1, 1, 1, 100, 1000), Mode::Pull);
        assert_eq!(p.repr(), ReprPolicy::Dense);
        // Hybrid carries its own representation knob.
        let h = Hybrid::default().with_repr(ReprPolicy::Sparse);
        assert_eq!(h.repr(), ReprPolicy::Sparse);
        assert_eq!(Hybrid::default().repr(), ReprPolicy::default());
    }
}
