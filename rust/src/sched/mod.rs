//! The Scheduler (paper Fig 4): decides push vs pull for each iteration
//! and informs the PEs at iteration start.
//!
//! The paper uses push in the beginning/ending iterations and pull in the
//! mid-term ones (§II-A, Algorithm 2). [`Hybrid`] implements the
//! direction-optimizing heuristic of Beamer et al. [33] — the scheme the
//! paper's scheduler (and Gunrock's) follows: switch push→pull when the
//! frontier's outgoing edges exceed `1/alpha` of the unexplored edges, and
//! pull→push when the frontier shrinks below `|V|/beta` vertices.

pub mod policies;

pub use policies::{DegreeAware, FrontierFraction, ModeTrace};

use crate::bfs::Mode;

/// Per-iteration mode decision.
pub trait ModePolicy {
    /// Decide the mode for the iteration about to run.
    ///
    /// * `bfs_level` — iteration index.
    /// * `frontier_size` — vertices in the current frontier.
    /// * `frontier_edges` — sum of out-degrees of the frontier.
    /// * `visited` — vertices visited so far.
    /// * `n`, `m` — |V|, |E| of the graph.
    fn decide(
        &mut self,
        bfs_level: u32,
        frontier_size: u64,
        frontier_edges: u64,
        visited: u64,
        n: u64,
        m: u64,
    ) -> Mode;

    /// Human-readable policy name for reports.
    fn name(&self) -> String;
}

/// Always run the same mode (the Fig 8 push-only / pull-only baselines).
pub struct Fixed(pub Mode);

impl ModePolicy for Fixed {
    fn decide(&mut self, _: u32, _: u64, _: u64, _: u64, _: u64, _: u64) -> Mode {
        self.0
    }

    fn name(&self) -> String {
        format!("{}-only", self.0)
    }
}

/// Direction-optimizing hybrid scheduler.
#[derive(Clone, Copy, Debug)]
pub struct Hybrid {
    /// push→pull when `frontier_edges > unexplored_edges / alpha`.
    pub alpha: f64,
    /// pull→push when `frontier_size < n / beta`.
    pub beta: f64,
    state: Mode,
}

impl Default for Hybrid {
    fn default() -> Self {
        // Beamer's published defaults.
        Self {
            alpha: 14.0,
            beta: 24.0,
            state: Mode::Push,
        }
    }
}

impl Hybrid {
    /// Hybrid policy with explicit thresholds.
    pub fn new(alpha: f64, beta: f64) -> Self {
        Self {
            alpha,
            beta,
            state: Mode::Push,
        }
    }
}

impl ModePolicy for Hybrid {
    fn decide(
        &mut self,
        _bfs_level: u32,
        frontier_size: u64,
        frontier_edges: u64,
        visited: u64,
        n: u64,
        m: u64,
    ) -> Mode {
        match self.state {
            Mode::Push => {
                // Unexplored edges approximated as m minus edges of
                // visited vertices ~ m * (1 - visited/n) (cheap signal the
                // hardware scheduler can compute on the fly).
                let unexplored =
                    (m as f64 * (1.0 - visited as f64 / n.max(1) as f64)).max(1.0);
                if frontier_edges as f64 > unexplored / self.alpha {
                    self.state = Mode::Pull;
                }
            }
            Mode::Pull => {
                if (frontier_size as f64) < n as f64 / self.beta {
                    self.state = Mode::Push;
                }
            }
        }
        self.state
    }

    fn name(&self) -> String {
        format!("hybrid(a={},b={})", self.alpha, self.beta)
    }
}

/// Scripted mode sequence (tests / ablations): iteration i runs `seq[i]`,
/// clamped to the last entry.
pub struct Scripted(pub Vec<Mode>);

impl ModePolicy for Scripted {
    fn decide(&mut self, bfs_level: u32, _: u64, _: u64, _: u64, _: u64, _: u64) -> Mode {
        let i = (bfs_level as usize).min(self.0.len().saturating_sub(1));
        self.0[i]
    }

    fn name(&self) -> String {
        "scripted".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_switches() {
        let mut p = Fixed(Mode::Pull);
        for i in 0..5 {
            assert_eq!(p.decide(i, 1, 1, 1, 100, 1000), Mode::Pull);
        }
    }

    #[test]
    fn hybrid_starts_push_switches_to_pull_and_back() {
        let mut p = Hybrid::default();
        // Tiny frontier: stays push.
        assert_eq!(p.decide(0, 1, 2, 1, 1000, 10000), Mode::Push);
        // Frontier edges explode past unexplored/alpha: go pull.
        assert_eq!(p.decide(1, 400, 9000, 400, 1000, 10000), Mode::Pull);
        // Large frontier: stays pull.
        assert_eq!(p.decide(2, 500, 500, 900, 1000, 10000), Mode::Pull);
        // Frontier collapses: back to push.
        assert_eq!(p.decide(3, 5, 10, 990, 1000, 10000), Mode::Push);
    }

    #[test]
    fn scripted_follows_sequence_and_clamps() {
        let mut p = Scripted(vec![Mode::Push, Mode::Pull]);
        assert_eq!(p.decide(0, 0, 0, 0, 1, 1), Mode::Push);
        assert_eq!(p.decide(1, 0, 0, 0, 1, 1), Mode::Pull);
        assert_eq!(p.decide(9, 0, 0, 0, 1, 1), Mode::Pull);
    }

    #[test]
    fn names_are_descriptive() {
        assert_eq!(Fixed(Mode::Push).name(), "push-only");
        assert!(Hybrid::default().name().starts_with("hybrid"));
    }
}
