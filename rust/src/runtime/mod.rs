//! XLA/PJRT runtime: loads the AOT-compiled Layer-2 artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them from the Rust request path. Python never runs here.
//!
//! The interchange format is HLO **text**: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that the crate's XLA (0.5.1) rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT-backed modules ([`client`], [`engine`]) sit behind the `xla`
//! cargo feature because the `xla` crate is a vendored dependency not
//! present in the offline registry; the artifact registry and the
//! CSR→dense conversion build unconditionally. Without the feature,
//! requesting the `xla` engine from [`crate::exec::EngineSpec`] returns
//! the typed [`EngineError::MissingFeature`](crate::exec::EngineError).

pub mod artifacts;
pub mod blocked;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod engine;

pub use artifacts::ArtifactStore;
#[cfg(feature = "xla")]
pub use client::XlaRuntime;
#[cfg(feature = "xla")]
pub use engine::{XlaBfsEngine, XlaBfsResult};
