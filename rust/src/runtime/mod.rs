//! XLA/PJRT runtime: loads the AOT-compiled Layer-2 artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them from the Rust request path. Python never runs here.
//!
//! The interchange format is HLO **text**: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that the crate's XLA (0.5.1) rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod artifacts;
pub mod blocked;
pub mod engine;

pub use artifacts::ArtifactStore;
pub use client::XlaRuntime;
pub use engine::XlaBfsEngine;
