//! XLA-backed BFS engine: executes the AOT-compiled `bfs_step` artifact
//! iteration-by-iteration from Rust. This proves the three-layer
//! architecture end-to-end (Pallas kernel → JAX model → HLO text → PJRT
//! execute) and is cross-validated against the bit-exact Rust engines.
//!
//! The artifact signature (see `python/compile/model.py`):
//!
//! ```text
//! bfs_step(adj f32[N,N], frontier f32[N], visited f32[N],
//!          level f32[N], bfs_level f32[1])
//!   -> (next_frontier f32[N], visited f32[N], level f32[N], num_new f32[1])
//! ```

use super::artifacts::ArtifactStore;
use super::blocked::{levels_to_u32, BlockedGraph};
use super::client::XlaRuntime;
use crate::graph::{Graph, VertexId};
use crate::Result;

/// Result of an XLA-path BFS.
#[derive(Clone, Debug)]
pub struct XlaBfsResult {
    /// Levels in the engine's u32 convention.
    pub levels: Vec<u32>,
    /// Iterations executed.
    pub iterations: u32,
    /// Vertices reached.
    pub reached: usize,
    /// Wall-clock seconds spent inside PJRT execute calls.
    pub execute_seconds: f64,
}

/// BFS engine running on the PJRT CPU client.
pub struct XlaBfsEngine {
    runtime: XlaRuntime,
    store: ArtifactStore,
}

impl XlaBfsEngine {
    /// Build from the default artifact directory.
    pub fn new() -> Result<Self> {
        Ok(Self {
            runtime: XlaRuntime::cpu()?,
            store: ArtifactStore::load_default()?,
        })
    }

    /// Build from an explicit artifact store.
    pub fn with_store(store: ArtifactStore) -> Result<Self> {
        Ok(Self {
            runtime: XlaRuntime::cpu()?,
            store,
        })
    }

    /// Artifact sizes available.
    pub fn sizes(&self) -> Vec<usize> {
        self.store.sizes("bfs_step")
    }

    /// Run BFS from `root` in a **single** PJRT execute using the
    /// `bfs_full` artifact (the whole level loop runs on-device under a
    /// `lax.while_loop`; see EXPERIMENTS.md §Perf for the speedup over
    /// per-iteration execution).
    pub fn run_full(&mut self, graph: &Graph, root: VertexId) -> Result<XlaBfsResult> {
        let n_real = graph.num_vertices();
        let artifact = self
            .store
            .best_fit("bfs_full", n_real)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bfs_full artifact fits {n_real} vertices (have {:?})",
                    self.store.sizes("bfs_full")
                )
            })?
            .clone();
        let blocked = BlockedGraph::build(graph, artifact.n)?;
        let (frontier, visited, level) = blocked.initial_state(root);
        let exe = self.runtime.load(&artifact.path)?;
        let n = artifact.n as i64;
        let inputs = [
            xla::Literal::vec1(&blocked.adj).reshape(&[n, n])?,
            xla::Literal::vec1(&frontier),
            xla::Literal::vec1(&visited),
            xla::Literal::vec1(&level),
        ];
        let t0 = std::time::Instant::now();
        let outs = exe.run(&inputs)?;
        let execute_seconds = t0.elapsed().as_secs_f64();
        anyhow::ensure!(outs.len() == 3, "expected 3 outputs, got {}", outs.len());
        let level_out = outs[1].to_vec::<f32>()?;
        let iterations = outs[2].to_vec::<f32>()?[0] as u32;
        let levels = levels_to_u32(&level_out, n_real);
        let reached = levels.iter().filter(|&&l| l != crate::bfs::INF).count();
        Ok(XlaBfsResult {
            levels,
            iterations,
            reached,
            execute_seconds,
        })
    }

    /// Run BFS from `root` using the smallest artifact that fits.
    pub fn run(&mut self, graph: &Graph, root: VertexId) -> Result<XlaBfsResult> {
        let n_real = graph.num_vertices();
        let artifact = self
            .store
            .best_fit("bfs_step", n_real)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bfs_step artifact fits {n_real} vertices (have {:?})",
                    self.sizes()
                )
            })?
            .clone();
        let blocked = BlockedGraph::build(graph, artifact.n)?;
        let (frontier0, visited0, level0) = blocked.initial_state(root);

        let exe = self.runtime.load(&artifact.path)?;
        let n = artifact.n as i64;
        let adj_lit = xla::Literal::vec1(&blocked.adj).reshape(&[n, n])?;
        let mut frontier = frontier0;
        let mut visited = visited0;
        let mut level = level0;

        let mut iterations = 0u32;
        let mut execute_seconds = 0.0f64;
        loop {
            let bfs_level = vec![iterations as f32];
            let inputs = [
                adj_lit.clone(),
                xla::Literal::vec1(&frontier),
                xla::Literal::vec1(&visited),
                xla::Literal::vec1(&level),
                xla::Literal::vec1(&bfs_level),
            ];
            let t0 = std::time::Instant::now();
            let outs = exe.run(&inputs)?;
            execute_seconds += t0.elapsed().as_secs_f64();
            anyhow::ensure!(outs.len() == 4, "expected 4 outputs, got {}", outs.len());
            frontier = outs[0].to_vec::<f32>()?;
            visited = outs[1].to_vec::<f32>()?;
            level = outs[2].to_vec::<f32>()?;
            let num_new = outs[3].to_vec::<f32>()?[0];
            iterations += 1;
            if num_new <= 0.0 {
                break;
            }
            anyhow::ensure!(iterations < 100_000, "xla bfs did not terminate");
        }

        let levels = levels_to_u32(&level, n_real);
        let reached = levels.iter().filter(|&&l| l != crate::bfs::INF).count();
        Ok(XlaBfsResult {
            levels,
            iterations,
            reached,
            execute_seconds,
        })
    }
}

// Integration tests for this engine live in rust/tests/runtime_hlo.rs and
// rust/tests/end_to_end.rs (they need `make artifacts` to have run).
