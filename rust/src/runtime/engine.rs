//! XLA-backed BFS engine: executes the AOT-compiled `bfs_step` artifact
//! iteration-by-iteration from Rust. This proves the three-layer
//! architecture end-to-end (Pallas kernel → JAX model → HLO text → PJRT
//! execute) and is cross-validated against the bit-exact Rust engines.
//!
//! The engine implements [`BfsEngine`] and is **born bound**:
//! [`XlaBfsEngine::bind`] picks the best-fit artifact for the graph,
//! densifies it and warm-compiles the executable, so an unprepared
//! engine is unrepresentable. `step` uploads the shared [`SearchState`]
//! as f32 vectors, runs one `bfs_step` execute, and writes the outputs
//! back into the bitmaps. The level-synchronous loop is the shared one
//! in [`crate::exec::driver`]. [`XlaBfsEngine::run_full`] remains the
//! on-device alternative (the whole level loop under a `lax.while_loop`
//! in one PJRT execute).
//!
//! The artifact signature (see `python/compile/model.py`):
//!
//! ```text
//! bfs_step(adj f32[N,N], frontier f32[N], visited f32[N],
//!          level f32[N], bfs_level f32[1])
//!   -> (next_frontier f32[N], visited f32[N], level f32[N], num_new f32[1])
//! ```

use super::artifacts::{Artifact, ArtifactStore};
use super::blocked::{levels_to_u32, BlockedGraph, INF_LEVEL};
use super::client::XlaRuntime;
use crate::bfs::Mode;
use crate::exec::{BfsEngine, SearchState, StepStats};
use crate::graph::{Graph, Partitioning, VertexId};
use crate::Result;
use std::sync::Arc;

/// Result of an XLA-path BFS.
#[derive(Clone, Debug)]
pub struct XlaBfsResult {
    /// Levels in the engine's u32 convention.
    pub levels: Vec<u32>,
    /// Iterations executed.
    pub iterations: u32,
    /// Vertices reached.
    pub reached: usize,
    /// Wall-clock seconds spent inside PJRT execute calls.
    pub execute_seconds: f64,
}

/// BFS engine running on the PJRT CPU client. Bound to one graph for
/// its whole lifetime: [`bind`](Self::bind) densifies the graph and
/// warm-compiles the artifact once, and every later `step`/`run` reuses
/// both.
pub struct XlaBfsEngine {
    runtime: XlaRuntime,
    store: ArtifactStore,
    graph: Arc<Graph>,
    part: Partitioning,
    artifact: Artifact,
    blocked: BlockedGraph,
    adj_lit: xla::Literal,
    /// First PJRT failure observed by `step` (the trait method ends the
    /// search early on failure; the error is parked here and
    /// [`run`](Self::run) surfaces it).
    step_error: Option<anyhow::Error>,
    /// Wall-clock seconds spent inside PJRT execute calls since `bind`.
    pub execute_seconds: f64,
}

impl XlaBfsEngine {
    /// Bind a graph using the default artifact directory. This is the
    /// constructor [`EngineSpec::bind`](crate::exec::EngineSpec::bind)
    /// goes through for the `xla` engine.
    pub fn bind(graph: impl Into<Arc<Graph>>, part: Partitioning) -> Result<Self> {
        Self::with_store(ArtifactStore::load_default()?, graph, part)
    }

    /// Bind a graph against an explicit artifact store: picks the
    /// best-fit `bfs_step` artifact, densifies the graph, and
    /// warm-compiles the executable so `step` never pays (or fails)
    /// compilation.
    pub fn with_store(
        store: ArtifactStore,
        graph: impl Into<Arc<Graph>>,
        part: Partitioning,
    ) -> Result<Self> {
        let graph = graph.into();
        let runtime = XlaRuntime::cpu()?;
        let n_real = graph.num_vertices();
        let artifact = store
            .best_fit("bfs_step", n_real)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bfs_step artifact fits {n_real} vertices (have {:?})",
                    store.sizes("bfs_step")
                )
            })?
            .clone();
        let blocked = BlockedGraph::build(&graph, artifact.n)?;
        let n = artifact.n as i64;
        let adj_lit = xla::Literal::vec1(&blocked.adj).reshape(&[n, n])?;
        runtime.load(&artifact.path)?;
        Ok(Self {
            runtime,
            store,
            graph,
            part,
            artifact,
            blocked,
            adj_lit,
            step_error: None,
            execute_seconds: 0.0,
        })
    }

    /// Artifact sizes available.
    pub fn sizes(&self) -> Vec<usize> {
        self.store.sizes("bfs_step")
    }

    /// Run BFS from `root` in a **single** PJRT execute using the
    /// `bfs_full` artifact (the whole level loop runs on-device under a
    /// `lax.while_loop`; see EXPERIMENTS.md §Perf for the speedup over
    /// per-iteration execution).
    pub fn run_full(&mut self, root: VertexId) -> Result<XlaBfsResult> {
        let n_real = self.graph.num_vertices();
        let artifact = self
            .store
            .best_fit("bfs_full", n_real)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bfs_full artifact fits {n_real} vertices (have {:?})",
                    self.store.sizes("bfs_full")
                )
            })?
            .clone();
        let blocked = BlockedGraph::build(&self.graph, artifact.n)?;
        let (frontier, visited, level) = blocked.initial_state(root);
        let exe = self.runtime.load(&artifact.path)?;
        let n = artifact.n as i64;
        let inputs = [
            xla::Literal::vec1(&blocked.adj).reshape(&[n, n])?,
            xla::Literal::vec1(&frontier),
            xla::Literal::vec1(&visited),
            xla::Literal::vec1(&level),
        ];
        let t0 = std::time::Instant::now();
        let outs = exe.run(&inputs)?;
        let execute_seconds = t0.elapsed().as_secs_f64();
        anyhow::ensure!(outs.len() == 3, "expected 3 outputs, got {}", outs.len());
        let level_out = outs[1].to_vec::<f32>()?;
        let iterations = outs[2].to_vec::<f32>()?[0] as u32;
        let levels = levels_to_u32(&level_out, n_real);
        let reached = levels.iter().filter(|&&l| l != crate::bfs::INF).count();
        Ok(XlaBfsResult {
            levels,
            iterations,
            reached,
            execute_seconds,
        })
    }

    /// Run BFS from `root` through the shared driver on the bound graph.
    pub fn run(&mut self, root: VertexId) -> Result<XlaBfsResult> {
        self.step_error = None;
        self.execute_seconds = 0.0;
        let mut state = SearchState::new(self.graph.num_vertices());
        let run = crate::exec::drive(self, &mut state, root, &mut crate::sched::Fixed(Mode::Push))?;
        if let Some(e) = self.step_error.take() {
            return Err(e);
        }
        Ok(XlaBfsResult {
            levels: run.levels,
            iterations: run.iterations,
            reached: run.reached,
            execute_seconds: self.execute_seconds,
        })
    }

    /// One `bfs_step` execute over the current state vectors; returns
    /// `(next_frontier, visited, level, num_new)` and accumulates the
    /// PJRT wall time into `execute_seconds`.
    fn execute_step(
        &mut self,
        frontier: &[f32],
        visited: &[f32],
        level: &[f32],
        bfs_level: u32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, u64)> {
        let exe = self.runtime.load(&self.artifact.path)?;
        let inputs = [
            self.adj_lit.clone(),
            xla::Literal::vec1(frontier),
            xla::Literal::vec1(visited),
            xla::Literal::vec1(level),
            xla::Literal::vec1(&[bfs_level as f32]),
        ];
        let t0 = std::time::Instant::now();
        let outs = exe.run(&inputs)?;
        self.execute_seconds += t0.elapsed().as_secs_f64();
        anyhow::ensure!(outs.len() == 4, "expected 4 outputs, got {}", outs.len());
        let num_new = outs[3].to_vec::<f32>()?[0].max(0.0) as u64;
        Ok((
            outs[0].to_vec::<f32>()?,
            outs[1].to_vec::<f32>()?,
            outs[2].to_vec::<f32>()?,
            num_new,
        ))
    }
}

impl BfsEngine for XlaBfsEngine {
    fn graph(&self) -> &Graph {
        &self.graph
    }

    fn partitioning(&self) -> Partitioning {
        self.part
    }

    /// One `bfs_step` execute. The dense mat-vec formulation is
    /// push-only, so the requested mode is ignored. A PJRT failure
    /// mid-run ends the search early (newly_visited = 0) and is parked
    /// in `step_error`; [`XlaBfsEngine::run`] returns it to the caller.
    fn step(&mut self, state: &mut SearchState, _mode: Mode) -> Result<StepStats> {
        let n_pad = self.blocked.n;
        let n_real = self.blocked.real_n;
        // Upload: bitmaps -> padded f32 vectors (padding stays visited,
        // as BlockedGraph::initial_state sets it, so the kernel never
        // activates it).
        let mut frontier = vec![0f32; n_pad];
        let mut visited = vec![0f32; n_pad];
        let mut level = vec![INF_LEVEL; n_pad];
        for v in state.current.iter() {
            frontier[v] = 1.0;
        }
        for v in state.visited.iter_ones() {
            visited[v] = 1.0;
        }
        for v in n_real..n_pad {
            visited[v] = 1.0;
        }
        for (v, &l) in state.levels.iter().enumerate() {
            if l != crate::bfs::INF {
                level[v] = l as f32;
            }
        }
        let (next_f, visited_f, level_f, num_new) =
            match self.execute_step(&frontier, &visited, &level, state.bfs_level) {
                Ok(outs) => outs,
                Err(e) => {
                    self.step_error.get_or_insert(e);
                    return Ok(StepStats::default());
                }
            };
        // Download: write the outputs back into the shared state. New
        // frontier vertices are staged with their out-degree so the
        // shared driver's insert-time signals stay exact.
        let graph = Arc::clone(&self.graph);
        for v in 0..n_real {
            if next_f[v] > 0.5 {
                state.next.insert(v as VertexId, graph.csr.degree(v as VertexId));
            }
            if visited_f[v] > 0.5 {
                state.visited.set(v);
            }
        }
        for (v, l) in levels_to_u32(&level_f, n_real).into_iter().enumerate() {
            state.levels[v] = l;
        }
        Ok(StepStats {
            newly_visited: num_new,
            ..StepStats::default()
        })
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

// Integration tests for this engine live in rust/tests/runtime_hlo.rs and
// rust/tests/end_to_end.rs (they need `make artifacts` to have run).
