//! PJRT client wrapper: load HLO text, compile once, execute many times.

use crate::Result;
use std::collections::HashMap;
use std::path::Path;

/// A compiled executable plus its entry metadata.
pub struct LoadedExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact path it was loaded from (diagnostics).
    pub source: String,
}

impl LoadedExecutable {
    /// Execute on literal inputs; returns the elements of the result
    /// tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// The PJRT runtime: one CPU client, a cache of compiled executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    cache: HashMap<String, LoadedExecutable>,
}

impl XlaRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
            cache: HashMap::new(),
        })
    }

    /// Backend platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact, caching by path.
    pub fn load(&mut self, path: &Path) -> Result<&LoadedExecutable> {
        let key = path.display().to_string();
        if !self.cache.contains_key(&key) {
            anyhow::ensure!(
                path.exists(),
                "artifact {key} missing - run `make artifacts` first"
            );
            let proto = xla::HloModuleProto::from_text_file(&key)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(
                key.clone(),
                LoadedExecutable {
                    exe,
                    source: key.clone(),
                },
            );
        }
        Ok(&self.cache[&key])
    }

    /// Number of compiled executables held.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = XlaRuntime::cpu().expect("pjrt cpu client");
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let mut rt = XlaRuntime::cpu().unwrap();
        let err = match rt.load(Path::new("/nonexistent/foo.hlo.txt")) {
            Ok(_) => panic!("expected load error"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
