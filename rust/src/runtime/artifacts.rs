//! Artifact registry: maps model variants to the HLO-text files emitted
//! by `python/compile/aot.py`, via the `artifacts/manifest.txt` it
//! writes (one line per artifact: `name\tn\ttile\tfile`).

use crate::Result;
use std::path::{Path, PathBuf};

/// One AOT-compiled model variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    /// Logical name (e.g. `bfs_step`).
    pub name: String,
    /// Padded vertex-dimension N the variant was lowered at.
    pub n: usize,
    /// Pallas tile size used in the kernel.
    pub tile: usize,
    /// HLO text path.
    pub path: PathBuf,
}

/// The set of artifacts produced by `make artifacts`.
#[derive(Clone, Debug, Default)]
pub struct ArtifactStore {
    /// All registered artifacts.
    pub artifacts: Vec<Artifact>,
    /// Directory the manifest lives in.
    pub dir: PathBuf,
}

impl ArtifactStore {
    /// Default artifacts directory: `$SCALABFS_ARTIFACTS` or
    /// `<repo>/artifacts` relative to the current dir / crate root.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("SCALABFS_ARTIFACTS") {
            return PathBuf::from(d);
        }
        // Walk up from cwd looking for artifacts/manifest.txt; fall back
        // to the crate-root-relative path used by `make artifacts`.
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.txt").exists() {
                return cand;
            }
            if !cur.pop() {
                break;
            }
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load the manifest from a directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        anyhow::ensure!(
            manifest.exists(),
            "no manifest at {} - run `make artifacts`",
            manifest.display()
        );
        let mut artifacts = Vec::new();
        for line in std::fs::read_to_string(&manifest)?.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            anyhow::ensure!(fields.len() == 4, "bad manifest line: {line}");
            artifacts.push(Artifact {
                name: fields[0].to_string(),
                n: fields[1].parse()?,
                tile: fields[2].parse()?,
                path: dir.join(fields[3]),
            });
        }
        Ok(Self {
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&Self::default_dir())
    }

    /// The smallest variant of `name` whose N is >= `min_n`.
    pub fn best_fit(&self, name: &str, min_n: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.name == name && a.n >= min_n)
            .min_by_key(|a| a.n)
    }

    /// All Ns available for a model name (sorted).
    pub fn sizes(&self, name: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.name == name)
            .map(|a| a.n)
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    #[test]
    fn parses_manifest_and_fits() {
        let dir = std::env::temp_dir().join("scalabfs_artifacts_test");
        write_manifest(
            &dir,
            "# comment\nbfs_step\t256\t64\tbfs_step_n256.hlo.txt\nbfs_step\t1024\t256\tbfs_step_n1024.hlo.txt\n",
        );
        let store = ArtifactStore::load(&dir).unwrap();
        assert_eq!(store.artifacts.len(), 2);
        assert_eq!(store.sizes("bfs_step"), vec![256, 1024]);
        assert_eq!(store.best_fit("bfs_step", 100).unwrap().n, 256);
        assert_eq!(store.best_fit("bfs_step", 300).unwrap().n, 1024);
        assert!(store.best_fit("bfs_step", 5000).is_none());
        assert!(store.best_fit("other", 1).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = ArtifactStore::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("scalabfs_artifacts_bad");
        write_manifest(&dir, "only two\tfields\n");
        assert!(ArtifactStore::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
