//! CSR → padded dense-tile conversion for the XLA functional path.
//!
//! The Pallas kernel formulates frontier expansion as a blocked boolean
//! mat-vec on 0/1 `f32` tiles (the MXU-shaped rethinking of the FPGA PE —
//! DESIGN.md §2). This module builds the dense matrix the artifact
//! expects: `adj[dst * N + src] = 1` for every edge `src → dst`, padded
//! to the artifact's N, so `reached = adj @ frontier` propagates along
//! outgoing edges.

use crate::graph::{Graph, VertexId};
use crate::Result;

/// A graph densified and padded for an N-sized artifact.
pub struct BlockedGraph {
    /// Padded dimension (the artifact's N).
    pub n: usize,
    /// Real vertex count (<= n).
    pub real_n: usize,
    /// Row-major `n x n` 0/1 matrix, `adj[dst * n + src]`.
    pub adj: Vec<f32>,
}

impl BlockedGraph {
    /// Densify `graph` into an `n`-padded matrix. Errors when the graph
    /// has more vertices than `n` (pick a bigger artifact) or when the
    /// dense footprint would be absurd (> 1 GiB).
    pub fn build(graph: &Graph, n: usize) -> Result<Self> {
        let real_n = graph.num_vertices();
        anyhow::ensure!(
            real_n <= n,
            "graph has {real_n} vertices but artifact is sized for {n}"
        );
        let bytes = n * n * 4;
        anyhow::ensure!(
            bytes <= 1 << 30,
            "dense {n}x{n} f32 would be {bytes} bytes; the XLA path is for small graphs"
        );
        let mut adj = vec![0f32; n * n];
        for src in 0..real_n {
            for &dst in graph.out_neighbors(src as VertexId) {
                adj[dst as usize * n + src] = 1.0;
            }
        }
        Ok(Self { n, real_n, adj })
    }

    /// Initial frontier/visited/level vectors for a root, padded.
    /// Levels use `f32` with `INF_LEVEL` for unreached (the artifact is
    /// all-f32; the engine converts back).
    pub fn initial_state(&self, root: VertexId) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut frontier = vec![0f32; self.n];
        let mut visited = vec![0f32; self.n];
        let mut level = vec![INF_LEVEL; self.n];
        frontier[root as usize] = 1.0;
        visited[root as usize] = 1.0;
        level[root as usize] = 0.0;
        // Padding vertices are marked visited so the kernel never
        // activates them.
        for v in self.real_n..self.n {
            visited[v] = 1.0;
        }
        (frontier, visited, level)
    }
}

/// The f32 encoding of "unreached" used by the artifacts.
pub const INF_LEVEL: f32 = 1.0e9;

/// Convert artifact levels back to the engine's u32 representation.
pub fn levels_to_u32(levels_f32: &[f32], real_n: usize) -> Vec<u32> {
    levels_f32[..real_n]
        .iter()
        .map(|&l| {
            if l >= INF_LEVEL {
                crate::bfs::INF
            } else {
                l as u32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn densify_places_edges_dst_major() {
        let g = generators::chain(3); // 0->1->2
        let b = BlockedGraph::build(&g, 4).unwrap();
        assert_eq!(b.adj[1 * 4 + 0], 1.0); // 0->1
        assert_eq!(b.adj[2 * 4 + 1], 1.0); // 1->2
        assert_eq!(b.adj.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    fn padding_vertices_start_visited() {
        let g = generators::chain(3);
        let b = BlockedGraph::build(&g, 8).unwrap();
        let (f, v, l) = b.initial_state(0);
        assert_eq!(f[0], 1.0);
        assert_eq!(v[0], 1.0);
        assert_eq!(l[0], 0.0);
        for i in 3..8 {
            assert_eq!(v[i], 1.0, "pad {i}");
        }
        assert_eq!(l[1], INF_LEVEL);
    }

    #[test]
    fn rejects_oversized_graph() {
        let g = generators::chain(10);
        assert!(BlockedGraph::build(&g, 4).is_err());
    }

    #[test]
    fn level_conversion_roundtrip() {
        let l = vec![0.0, 2.0, INF_LEVEL, 5.0];
        let u = levels_to_u32(&l, 3);
        assert_eq!(u, vec![0, 2, crate::bfs::INF]);
    }
}
