//! ForeGraph-style edge-centric single-channel baseline (paper §II-D).
//!
//! General-purpose FPGA graph frameworks stream the *whole* edge list
//! every iteration (edge-centric model), which "limits their performances
//! on BFS": ForeGraph reaches only ~410 MTEPS on soc-LiveJournal with one
//! DDR4 channel. This module models that processing style so Fig 12's
//! context (why vertex-centric + bitmaps wins per-channel) is
//! reproducible, not just quoted.
//!
//! [`EdgeCentricEngine`] implements [`BfsEngine`]: each step scans the
//! full edge list (the edge-centric scatter), updating the shared
//! [`SearchState`] with push semantics, and charges the whole edge
//! array's bytes to the single channel. [`estimate`] drives it through
//! the shared level-synchronous loop and converts the streamed bytes
//! into DDR4 seconds.

use crate::bfs::traffic::IterTraffic;
use crate::bfs::Mode;
use crate::exec::{BfsEngine, SearchState, StepStats};
use crate::graph::{Graph, Partitioning, VertexId};
use crate::sched::Fixed;
use crate::Result;

/// Single-channel parameters for the edge-centric baseline.
#[derive(Clone, Copy, Debug)]
pub struct EdgeCentricConfig {
    /// Channel bandwidth, bytes/s (DDR4: 19.2 GB/s theoretical, ~12-15
    /// effective for streaming).
    pub channel_bw: f64,
    /// Bytes per edge record (src + dst).
    pub edge_bytes: f64,
    /// Streaming efficiency (row activations, turnarounds).
    pub efficiency: f64,
}

impl Default for EdgeCentricConfig {
    fn default() -> Self {
        Self {
            channel_bw: 19.2e9,
            edge_bytes: 8.0,
            efficiency: 0.75,
        }
    }
}

/// Result of the edge-centric estimate.
#[derive(Clone, Copy, Debug)]
pub struct EdgeCentricResult {
    /// BFS iterations (graph depth).
    pub iterations: u32,
    /// Total edges streamed (|E| per iteration).
    pub edges_streamed: u64,
    /// Execution seconds.
    pub seconds: f64,
    /// Graph500 GTEPS (traversed edges / time — same numerator as
    /// ScalaBFS, so the comparison is apples-to-apples).
    pub gteps: f64,
}

/// The edge-centric baseline engine: every iteration streams the entire
/// edge list through one memory channel, testing each edge against the
/// current frontier. Direction-agnostic — there is no pull variant, so
/// `step` ignores the requested mode.
pub struct EdgeCentricEngine {
    graph: std::sync::Arc<Graph>,
    part: Partitioning,
    /// Channel parameters used by [`estimate`].
    pub cfg: EdgeCentricConfig,
}

impl EdgeCentricEngine {
    /// New baseline engine. Any requested partitioning is irrelevant:
    /// the edge-centric baseline is single-channel by definition, so
    /// its traffic is always attributed to one PE / one PG regardless
    /// of the sweep's PC/PE point (sweeps time that one channel with
    /// the HBM model; the DDR4 Fig-12 number comes from [`estimate`]).
    pub fn new(graph: impl Into<std::sync::Arc<Graph>>, cfg: EdgeCentricConfig) -> Self {
        Self {
            graph: graph.into(),
            part: Partitioning::new(1, 1),
            cfg,
        }
    }
}

impl BfsEngine for EdgeCentricEngine {
    fn graph(&self) -> &Graph {
        &self.graph
    }

    fn partitioning(&self) -> Partitioning {
        self.part
    }

    fn step(&mut self, state: &mut SearchState, _mode: Mode) -> Result<StepStats> {
        let graph = self.graph.as_ref();
        let mut it = IterTraffic::new(
            state.bfs_level,
            Mode::Push,
            self.part.num_pes,
            self.part.num_pgs,
        );
        it.frontier_size = state.frontier_size;
        // Edge-centric scatter: the *modeled* channel streams the whole
        // edge array regardless of frontier size (the byte/neighbor
        // counters below are set from |E| directly). The host-side
        // discovery computation walks only the frontier — results are
        // identical (visited test-and-set dedups, order-independent)
        // and small-frontier iterations stay O(frontier) on the host.
        it.neighbors_streamed = graph.num_edges();
        it.per_pg_edge_bytes[0] = (graph.num_edges() as f64 * self.cfg.edge_bytes) as u64;
        for u in state.current.iter() {
            for &w in graph.out_neighbors(u as VertexId) {
                if !state.visited.test_and_set(w as usize) {
                    state.next.insert(w, graph.csr.degree(w));
                    state.levels[w as usize] = state.bfs_level + 1;
                    it.newly_visited += 1;
                }
            }
        }
        Ok(StepStats {
            newly_visited: it.newly_visited,
            traffic: Some(it),
            ..StepStats::default()
        })
    }

    fn name(&self) -> &'static str {
        "edge-centric"
    }
}

/// Estimate edge-centric BFS performance: every iteration streams the
/// full edge list through the single channel.
pub fn estimate(
    g: &std::sync::Arc<Graph>,
    root: VertexId,
    cfg: EdgeCentricConfig,
) -> EdgeCentricResult {
    let mut engine = EdgeCentricEngine::new(std::sync::Arc::clone(g), cfg);
    let run = engine
        .run(root, &mut Fixed(Mode::Push))
        .expect("the edge-centric step is infallible");
    let iterations = run.iterations;
    let edges_streamed = g.num_edges() * iterations as u64;
    let bytes = edges_streamed as f64 * cfg.edge_bytes;
    let seconds = bytes / (cfg.channel_bw * cfg.efficiency);
    EdgeCentricResult {
        iterations,
        edges_streamed,
        seconds,
        gteps: run.traversed_edges as f64 / seconds / 1e9,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::reference;
    use crate::graph::generators;

    #[test]
    fn edge_centric_streams_full_graph_each_iteration() {
        let g = std::sync::Arc::new(generators::chain(10));
        let res = estimate(&g, 0, EdgeCentricConfig::default());
        assert_eq!(res.iterations, 10);
        assert_eq!(res.edges_streamed, 9 * 10);
    }

    #[test]
    fn edge_centric_levels_match_reference() {
        let g = std::sync::Arc::new(generators::rmat_graph500(9, 8, 3));
        let root = reference::sample_roots(&g, 1, 3)[0];
        let run = EdgeCentricEngine::new(g.clone(), EdgeCentricConfig::default())
            .run(root, &mut Fixed(Mode::Push))
            .unwrap();
        assert_eq!(run.levels, reference::bfs(&g, root).levels);
    }

    #[test]
    fn edge_centric_lands_in_foregraph_ballpark() {
        // On an LJ-like scale-free graph the model should land in the
        // hundreds-of-MTEPS range (ForeGraph: ~410 MTEPS), far below a
        // GTEPS-class vertex-centric design.
        let g = std::sync::Arc::new(generators::rmat_graph500(13, 14, 77));
        let root = reference::sample_roots(&g, 1, 1)[0];
        let res = estimate(&g, root, EdgeCentricConfig::default());
        assert!(
            res.gteps > 0.05 && res.gteps < 2.0,
            "gteps={}",
            res.gteps
        );
    }
}
