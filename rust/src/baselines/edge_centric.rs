//! ForeGraph-style edge-centric single-channel baseline (paper §II-D).
//!
//! General-purpose FPGA graph frameworks stream the *whole* edge list
//! every iteration (edge-centric model), which "limits their performances
//! on BFS": ForeGraph reaches only ~410 MTEPS on soc-LiveJournal with one
//! DDR4 channel. This module models that processing style so Fig 12's
//! context (why vertex-centric + bitmaps wins per-channel) is
//! reproducible, not just quoted.

use crate::bfs::reference;
use crate::graph::{Graph, VertexId};

/// Single-channel parameters for the edge-centric baseline.
#[derive(Clone, Copy, Debug)]
pub struct EdgeCentricConfig {
    /// Channel bandwidth, bytes/s (DDR4: 19.2 GB/s theoretical, ~12-15
    /// effective for streaming).
    pub channel_bw: f64,
    /// Bytes per edge record (src + dst).
    pub edge_bytes: f64,
    /// Streaming efficiency (row activations, turnarounds).
    pub efficiency: f64,
}

impl Default for EdgeCentricConfig {
    fn default() -> Self {
        Self {
            channel_bw: 19.2e9,
            edge_bytes: 8.0,
            efficiency: 0.75,
        }
    }
}

/// Result of the edge-centric estimate.
#[derive(Clone, Copy, Debug)]
pub struct EdgeCentricResult {
    /// BFS iterations (graph depth).
    pub iterations: u32,
    /// Total edges streamed (|E| per iteration).
    pub edges_streamed: u64,
    /// Execution seconds.
    pub seconds: f64,
    /// Graph500 GTEPS (traversed edges / time — same numerator as
    /// ScalaBFS, so the comparison is apples-to-apples).
    pub gteps: f64,
}

/// Estimate edge-centric BFS performance: every iteration streams the
/// full edge list through the single channel.
pub fn estimate(g: &Graph, root: VertexId, cfg: EdgeCentricConfig) -> EdgeCentricResult {
    let r = reference::bfs(g, root);
    let iterations = r.depth;
    let edges_streamed = g.num_edges() * iterations as u64;
    let bytes = edges_streamed as f64 * cfg.edge_bytes;
    let seconds = bytes / (cfg.channel_bw * cfg.efficiency);
    let traversed: u64 = r
        .levels
        .iter()
        .enumerate()
        .filter(|(_, &l)| l != crate::bfs::INF)
        .map(|(v, _)| g.csr.degree(v as VertexId))
        .sum();
    EdgeCentricResult {
        iterations,
        edges_streamed,
        seconds,
        gteps: traversed as f64 / seconds / 1e9,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn edge_centric_streams_full_graph_each_iteration() {
        let g = generators::chain(10);
        let res = estimate(&g, 0, EdgeCentricConfig::default());
        assert_eq!(res.iterations, 10);
        assert_eq!(res.edges_streamed, 9 * 10);
    }

    #[test]
    fn edge_centric_lands_in_foregraph_ballpark() {
        // On an LJ-like scale-free graph the model should land in the
        // hundreds-of-MTEPS range (ForeGraph: ~410 MTEPS), far below a
        // GTEPS-class vertex-centric design.
        let g = generators::rmat_graph500(13, 14, 77);
        let root = reference::sample_roots(&g, 1, 1)[0];
        let res = estimate(&g, root, EdgeCentricConfig::default());
        assert!(
            res.gteps > 0.05 && res.gteps < 2.0,
            "gteps={}",
            res.gteps
        );
    }
}
