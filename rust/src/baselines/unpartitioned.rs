//! The Fig 11 baseline: unpartitioned edge placement.
//!
//! Identical functional behaviour to ScalaBFS, but the CSR/CSC edge data
//! is *not* interleaved across PCs: it fills PC0, then PC1, … . Every
//! PG's HBM reader must therefore reach across the switch network to the
//! data-holding PCs, paying the Fig 3 crossing penalty, and service
//! concentrates on the few PCs with data ("stored in the PCs with small
//! suffixes ... unbalanced accesses", §VI-E).

use crate::bfs::bitmap::BfsRun;
use crate::sim::config::{Placement, SimConfig};
use crate::sim::results::SimResult;
use crate::sim::throughput::ThroughputSim;

/// Simulate the same functional run under baseline placement.
pub fn simulate_baseline(
    run: &BfsRun,
    mut cfg: SimConfig,
    graph_name: &str,
    graph_bytes: u64,
) -> SimResult {
    cfg.placement = Placement::Unpartitioned;
    ThroughputSim::new(cfg).simulate(run, &format!("{graph_name}(baseline)"), graph_bytes)
}

/// Number of PCs the unpartitioned data occupies (sequential fill).
pub fn data_pcs(graph_bytes: u64, pc_capacity: u64, num_pcs: usize) -> usize {
    ((graph_bytes as f64 / pc_capacity as f64).ceil() as usize).clamp(1, num_pcs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::bitmap::run_bfs;
    use crate::bfs::reference;
    use crate::graph::generators;
    use crate::sched::Hybrid;

    #[test]
    fn baseline_is_slower_and_uses_less_bandwidth() {
        let g = std::sync::Arc::new(generators::rmat_graph500(12, 16, 31));
        let root = reference::sample_roots(&g, 1, 31)[0];
        let cfg = SimConfig::u280(16, 32);
        let run = run_bfs(&g, cfg.part, root, &mut Hybrid::default());
        let bytes = g.csr.footprint_bytes(4) + g.csc.footprint_bytes(4);
        let scala = ThroughputSim::new(cfg.clone()).simulate(&run, &g.name, bytes);
        let base = simulate_baseline(&run, cfg, &g.name, bytes);
        assert!(scala.gteps > base.gteps * 2.0, "{} vs {}", scala.gteps, base.gteps);
        assert!(scala.aggregate_bw > base.aggregate_bw);
    }

    #[test]
    fn data_pcs_sequential_fill() {
        assert_eq!(data_pcs(100, 1000, 32), 1);
        assert_eq!(data_pcs(1001, 1000, 32), 2);
        assert_eq!(data_pcs(u64::MAX / 2, 1000, 32), 32);
    }
}
