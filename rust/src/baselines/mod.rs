//! Baseline systems the paper compares against.
//!
//! * [`unpartitioned`] — the Fig 11 baseline: same PEs, but edge data
//!   placed sequentially from PC0 so readers cross the HBM switch (a
//!   *placement* variant timed by the throughput simulator, not a
//!   separate functional engine).
//! * [`edge_centric`] — a ForeGraph-style edge-centric single-channel
//!   processor (the §II-D context for Fig 12's per-channel comparison),
//!   a full [`crate::exec::BfsEngine`] implementation.
//! * Push-only / pull-only baselines are [`crate::sched::Fixed`] policies
//!   over the main engine (Fig 8).

pub mod unpartitioned;
pub mod edge_centric;
