//! Baseline systems the paper compares against.
//!
//! * [`unpartitioned`] — the Fig 11 baseline: same PEs, but edge data
//!   placed sequentially from PC0 so readers cross the HBM switch.
//! * [`edge_centric`] — a ForeGraph-style edge-centric single-channel
//!   processor (the §II-D context for Fig 12's per-channel comparison).
//! * Push-only / pull-only baselines are [`crate::sched::Fixed`] policies
//!   over the main engine (Fig 8).

pub mod unpartitioned;
pub mod edge_centric;
