"""Layer-1 Pallas kernel: blocked frontier expansion.

The FPGA PE's hot operation -- stream the neighbor lists of the frontier
and test bitmap bits -- is rethought for the TPU as a blocked boolean
mat-vec on the MXU (DESIGN.md section 2):

    reached[i] = 1  iff  exists j with adj[i, j] == 1 and frontier[j] == 1
               = (adj @ frontier)[i] > 0

over 0/1 float32 tiles. The adjacency matrix is streamed tile-by-tile
through VMEM via the BlockSpec grid -- the role the HBM reader + AXI
bursts play on the U280 -- and the accumulator lives across the
column-tile grid dimension (double-buffered by Pallas).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which both pytest and
the Rust runtime execute. Real-TPU tiling notes live in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expand_kernel(adj_ref, frontier_ref, out_ref):
    """One (TR, TC) tile: accumulate adj_tile @ frontier_tile into out."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # MXU-shaped: a (TR, TC) x (TC,) dot per tile. 0/1 values in f32 --
    # the accumulated count is the number of active in-neighbors seen so
    # far, thresholded by the caller.
    out_ref[...] += jnp.dot(
        adj_ref[...], frontier_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_c"))
def frontier_expand(adj, frontier, *, tile_r=128, tile_c=128):
    """Blocked mat-vec: returns per-vertex active-in-neighbor counts.

    Args:
      adj: (n, n) float32 0/1 matrix, adj[dst, src] = 1 for edge src->dst.
      frontier: (n,) float32 0/1 current-frontier vector.
      tile_r / tile_c: VMEM tile shape; n must divide evenly.

    Returns:
      (n,) float32 counts (not yet thresholded).
    """
    n = adj.shape[0]
    assert adj.shape == (n, n), adj.shape
    assert frontier.shape == (n,), frontier.shape
    assert n % tile_r == 0 and n % tile_c == 0, (n, tile_r, tile_c)
    grid = (n // tile_r, n // tile_c)
    return pl.pallas_call(
        _expand_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, tile_c), lambda i, j: (i, j)),
            pl.BlockSpec((tile_c,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_r,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(adj, frontier)


def vmem_bytes(tile_r: int, tile_c: int) -> int:
    """Estimated VMEM footprint of one grid step (perf model for the
    DESIGN.md roofline discussion): adj tile + frontier tile + out tile,
    double-buffered."""
    per_step = (tile_r * tile_c + tile_c + tile_r) * 4
    return 2 * per_step
