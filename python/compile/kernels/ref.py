"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its `*_ref` twin to float
equality on 0/1 inputs; pytest + hypothesis sweep shapes and random
graphs (python/tests/test_kernels.py).
"""

import jax.numpy as jnp

INF_LEVEL = 1.0e9


def frontier_expand_ref(adj, frontier):
    """Reference mat-vec: per-vertex active-in-neighbor counts."""
    return adj @ frontier


def bitmap_update_ref(counts, visited, level, bfs_level):
    """Reference Algorithm-2 P3 update."""
    new = jnp.where(counts > 0.0, 1.0, 0.0) * (1.0 - visited)
    next_frontier = new
    visited_out = jnp.minimum(visited + new, 1.0)
    level_out = jnp.where(new > 0.0, bfs_level[0] + 1.0, level)
    return next_frontier, visited_out, level_out


def popcount_ref(x):
    """Reference popcount."""
    return jnp.sum(x, keepdims=True)


def bfs_step_ref(adj, frontier, visited, level, bfs_level):
    """Reference one-iteration BFS step (the Layer-2 contract)."""
    counts = frontier_expand_ref(adj, frontier)
    next_frontier, visited_out, level_out = bitmap_update_ref(
        counts, visited, level, bfs_level
    )
    num_new = popcount_ref(next_frontier)
    return next_frontier, visited_out, level_out, num_new


def bfs_full_ref(adj, root):
    """Run BFS to completion with the reference step (tests only)."""
    n = adj.shape[0]
    frontier = jnp.zeros((n,), jnp.float32).at[root].set(1.0)
    visited = jnp.zeros((n,), jnp.float32).at[root].set(1.0)
    level = jnp.full((n,), INF_LEVEL, jnp.float32).at[root].set(0.0)
    it = 0
    while True:
        bfs_level = jnp.array([float(it)], jnp.float32)
        frontier, visited, level, num_new = bfs_step_ref(
            adj, frontier, visited, level, bfs_level
        )
        it += 1
        if float(num_new[0]) == 0.0 or it > n:
            break
    return level
