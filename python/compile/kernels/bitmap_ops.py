"""Layer-1 Pallas kernel: bitmap/level update (the P2/P3 stages).

Given the raw expansion counts, compute the Algorithm-2 state update the
FPGA PEs perform against their double-pump BRAM bitmaps and URAM level
array:

    new           = (counts > 0) & ~visited
    next_frontier = new
    visited'      = visited | new
    level'        = new ? bfs_level + 1 : level

All state is 0/1 (or level) float32 vectors, tiled through VMEM. This is
VPU-shaped elementwise work, deliberately separate from the MXU-shaped
expansion kernel -- mirroring the paper's decoupling of memory access
(P1/HBM reader) from bitmap processing (P2/P3).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(counts_ref, visited_ref, level_ref, bfs_level_ref,
                   next_ref, visited_out_ref, level_out_ref):
    counts = counts_ref[...]
    visited = visited_ref[...]
    level = level_ref[...]
    bfs_level = bfs_level_ref[0]
    new = jnp.where(counts > 0.0, 1.0, 0.0) * (1.0 - visited)
    next_ref[...] = new
    visited_out_ref[...] = jnp.minimum(visited + new, 1.0)
    level_out_ref[...] = jnp.where(new > 0.0, bfs_level + 1.0, level)


@functools.partial(jax.jit, static_argnames=("tile",))
def bitmap_update(counts, visited, level, bfs_level, *, tile=128):
    """Apply the Algorithm-2 P3 update, tiled.

    Args:
      counts: (n,) f32 expansion counts from `frontier_expand`.
      visited: (n,) f32 0/1 visited map.
      level: (n,) f32 levels (1e9 = unreached).
      bfs_level: (1,) f32 current iteration index.
      tile: VMEM tile length; n must divide evenly.

    Returns:
      (next_frontier, visited', level') -- each (n,) f32.
    """
    n = counts.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    vec_spec = pl.BlockSpec((tile,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, vec_spec, scalar_spec],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(counts, visited, level, bfs_level)


def _popcount_kernel(x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(x_ref[...], keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile",))
def popcount(x, *, tile=128):
    """Sum of a 0/1 f32 vector as a (1,) array (frontier size -- the
    scheduler's switching signal), tiled through VMEM."""
    n = x.shape[0]
    assert n % tile == 0, (n, tile)
    return pl.pallas_call(
        _popcount_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(x)
