"""AOT compile path: lower the Layer-2 model to HLO **text** artifacts.

Run once by `make artifacts`; Python never runs on the request path.

HLO text (not `.serialize()` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the Rust side's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts:
  artifacts/bfs_step_n{N}.hlo.txt   for N in SIZES
  artifacts/manifest.txt            name\tN\ttile\tfile  per line
"""

import argparse
import functools
import os

import jax
from jax._src.lib import xla_client as xc

from .model import bfs_full, bfs_step, example_args

# Padded sizes the Rust runtime can pick from. Dense n^2 f32 matrices:
# 256 KiB, 4 MiB, 16 MiB respectively -- the XLA functional path is for
# small graphs (DESIGN.md section 2); the Rust engines cover the rest.
SIZES = (256, 1024, 2048)
TILE = 512


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bfs_step(n: int, tile: int = TILE) -> str:
    """Lower bfs_step at size n to HLO text (tile clamped to n)."""
    tile = min(tile, n)
    fn = functools.partial(bfs_step, tile=tile)
    lowered = jax.jit(fn).lower(*example_args(n, tile))
    return to_hlo_text(lowered)


def lower_bfs_full(n: int, tile: int = TILE) -> str:
    """Lower the whole-BFS while-loop variant at size n."""
    tile = min(tile, n)
    fn = functools.partial(bfs_full, tile=tile)
    lowered = jax.jit(fn).lower(*example_args(n, tile)[:4])
    return to_hlo_text(lowered)


def build(out_dir: str, sizes=SIZES, tile: int = TILE) -> list[str]:
    """Write all artifacts + manifest; returns the file list."""
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    written = []
    for n in sizes:
        n_tile = min(tile, n)
        for name, text in [
            ("bfs_step", lower_bfs_step(n, n_tile)),
            ("bfs_full", lower_bfs_full(n, n_tile)),
        ]:
            fname = f"{name}_n{n}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest_lines.append(f"{name}\t{n}\t{n_tile}\t{fname}")
            written.append(path)
            print(f"wrote {path} ({len(text)} chars)")
    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# name\tN\ttile\tfile\n")
        f.write("\n".join(manifest_lines) + "\n")
    written.append(manifest)
    print(f"wrote {manifest}")
    return written


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out", default="../artifacts", help="artifact output directory"
    )
    parser.add_argument(
        "--sizes",
        default=",".join(str(s) for s in SIZES),
        help="comma-separated padded sizes",
    )
    args = parser.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    build(args.out, sizes=sizes)


if __name__ == "__main__":
    main()
