"""Layer-2 JAX model: one BFS iteration over the dense-blocked graph.

`bfs_step` is the computation the Rust coordinator executes through PJRT
every iteration. It composes the two Layer-1 Pallas kernels:

  frontier_expand (MXU-shaped blocked mat-vec)  ->  counts
  bitmap_update   (VPU-shaped P2/P3 state update)
  popcount        (frontier size -- the scheduler's signal)

Signature (all float32; the Rust side mirrors it in runtime/engine.rs):

  bfs_step(adj (n,n), frontier (n,), visited (n,), level (n,),
           bfs_level (1,))
    -> (next_frontier (n,), visited' (n,), level' (n,), num_new (1,))

Pull mode is the same artifact applied to adj^T -- the CSR/CSC duality of
the paper collapses to a transpose in the dense formulation; the Rust
engine picks the orientation when densifying.
"""

import functools

import jax

from .kernels.bitmap_ops import bitmap_update, popcount
from .kernels.frontier_expand import frontier_expand


@functools.partial(jax.jit, static_argnames=("tile",))
def bfs_step(adj, frontier, visited, level, bfs_level, *, tile=128):
    """One Algorithm-2 iteration (see module docstring)."""
    counts = frontier_expand(adj, frontier, tile_r=tile, tile_c=tile)
    next_frontier, visited_out, level_out = bitmap_update(
        counts, visited, level, bfs_level, tile=tile
    )
    num_new = popcount(next_frontier, tile=tile)
    return next_frontier, visited_out, level_out, num_new


@functools.partial(jax.jit, static_argnames=("tile",))
def bfs_full(adj, frontier, visited, level, *, tile=128):
    """Whole-BFS-on-device: iterate `bfs_step` under a lax.while_loop
    until the frontier empties.

    One PJRT execute call replaces one per BFS level — the Layer-2
    optimization recorded in EXPERIMENTS.md §Perf. Returns
    (visited, level, iterations as f32[1]).
    """
    import jax.numpy as jnp

    n = adj.shape[0]

    def cond(state):
        frontier, _, _, i = state
        return jnp.logical_and(jnp.sum(frontier) > 0.0, i < n + 1)

    def body(state):
        frontier, visited, level, i = state
        bfs_level = jnp.reshape(i.astype(jnp.float32), (1,))
        nf, nv, nl, _ = bfs_step(adj, frontier, visited, level, bfs_level, tile=tile)
        return nf, nv, nl, i + 1

    state = (frontier, visited, level, jnp.int32(0))
    frontier, visited, level, i = jax.lax.while_loop(cond, body, state)
    return visited, level, jnp.reshape(i.astype(jnp.float32), (1,))


def example_args(n, tile=128):
    """ShapeDtypeStructs for AOT lowering at size n."""
    import jax.numpy as jnp

    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )
