"""Layer-2 model correctness: bfs_step vs the reference step, and full
BFS runs vs a plain-python BFS on random graphs."""

import collections

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import bfs_step, example_args

INF = ref.INF_LEVEL


def rand_graph(n, density, seed):
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    return adj


def python_bfs_levels(adj, root):
    """Plain queue BFS over the dense matrix adj[dst, src]."""
    n = adj.shape[0]
    levels = [INF] * n
    levels[root] = 0.0
    q = collections.deque([root])
    while q:
        u = q.popleft()
        for v in range(n):
            if adj[v, u] > 0 and levels[v] == INF:
                levels[v] = levels[u] + 1
                q.append(v)
    return np.array(levels, np.float32)


def run_xla_bfs(adj_np, root, tile=64):
    """Iterate bfs_step to completion (mirrors the Rust engine loop)."""
    n = adj_np.shape[0]
    adj = jnp.array(adj_np)
    frontier = jnp.zeros((n,), jnp.float32).at[root].set(1.0)
    visited = jnp.zeros((n,), jnp.float32).at[root].set(1.0)
    level = jnp.full((n,), INF, jnp.float32).at[root].set(0.0)
    for it in range(n + 1):
        bl = jnp.array([float(it)], jnp.float32)
        frontier, visited, level, num_new = bfs_step(
            adj, frontier, visited, level, bl, tile=tile
        )
        if float(num_new[0]) == 0.0:
            break
    return np.array(level)


class TestBfsStep:
    def test_single_step_matches_ref(self):
        n = 128
        adj = jnp.array(rand_graph(n, 0.05, 0))
        frontier = jnp.zeros((n,), jnp.float32).at[3].set(1.0)
        visited = jnp.zeros((n,), jnp.float32).at[3].set(1.0)
        level = jnp.full((n,), INF, jnp.float32).at[3].set(0.0)
        bl = jnp.array([0.0], jnp.float32)
        got = bfs_step(adj, frontier, visited, level, bl, tile=64)
        want = ref.bfs_step_ref(adj, frontier, visited, level, bl)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.array(g), np.array(w))

    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_full_bfs_matches_python(self, seed):
        n = 128
        adj = rand_graph(n, 0.03, seed)
        levels = run_xla_bfs(adj, root=0)
        want = python_bfs_levels(adj, 0)
        np.testing.assert_allclose(levels, want)

    def test_disconnected_stays_inf(self):
        n = 128
        adj = np.zeros((n, n), np.float32)
        adj[1, 0] = 1.0  # 0 -> 1 only
        levels = run_xla_bfs(adj, root=0)
        assert levels[0] == 0.0 and levels[1] == 1.0
        assert np.all(levels[2:] == INF)

    def test_chain_depth(self):
        n = 128
        adj = np.zeros((n, n), np.float32)
        for i in range(n - 1):
            adj[i + 1, i] = 1.0
        levels = run_xla_bfs(adj, root=0)
        np.testing.assert_allclose(levels, np.arange(n, dtype=np.float32))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.01, 0.1))
    def test_hypothesis_full_runs(self, seed, density):
        n = 128
        adj = rand_graph(n, density, seed)
        levels = run_xla_bfs(adj, root=int(seed % n))
        want = python_bfs_levels(adj, int(seed % n))
        np.testing.assert_allclose(levels, want)

    def test_bfs_full_matches_iterated_steps(self):
        from compile.model import bfs_full

        n = 128
        adj_np = rand_graph(n, 0.04, 9)
        adj = jnp.array(adj_np)
        root = 3
        frontier = jnp.zeros((n,), jnp.float32).at[root].set(1.0)
        visited = jnp.zeros((n,), jnp.float32).at[root].set(1.0)
        level = jnp.full((n,), INF, jnp.float32).at[root].set(0.0)
        v_full, l_full, iters = bfs_full(adj, frontier, visited, level, tile=64)
        want = run_xla_bfs(adj_np, root)
        np.testing.assert_allclose(np.array(l_full), want)
        assert float(iters[0]) >= 1.0
        # visited == reached set
        reached = (np.array(l_full) < INF).astype(np.float32)
        np.testing.assert_allclose(np.array(v_full), reached)

    def test_example_args_shapes(self):
        args = example_args(256)
        assert args[0].shape == (256, 256)
        assert args[1].shape == (256,)
        assert args[4].shape == (1,)
        assert all(a.dtype == jnp.float32 for a in args)
