"""Layer-1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps tile sizes, densities and seeds; every kernel must
match its ref.py twin exactly on 0/1 inputs (float32 sums of 0/1 values
are exact well past any realistic degree).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bitmap_ops import bitmap_update, popcount
from compile.kernels.frontier_expand import frontier_expand, vmem_bytes

SIZES = [128, 256]
TILES = [64, 128]


def rand_graph(n, density, seed):
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    return jnp.array(adj)


def rand_mask(n, p, seed):
    rng = np.random.default_rng(seed)
    return jnp.array((rng.random(n) < p).astype(np.float32))


class TestFrontierExpand:
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("tile", TILES)
    def test_matches_ref(self, n, tile):
        adj = rand_graph(n, 0.05, n + tile)
        f = rand_mask(n, 0.2, n * tile)
        got = frontier_expand(adj, f, tile_r=tile, tile_c=tile)
        want = ref.frontier_expand_ref(adj, f)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=0, atol=0)

    def test_empty_frontier_all_zero(self):
        adj = rand_graph(128, 0.1, 1)
        z = jnp.zeros((128,), jnp.float32)
        got = frontier_expand(adj, z, tile_r=64, tile_c=64)
        assert float(jnp.sum(got)) == 0.0

    def test_full_frontier_counts_in_degree(self):
        adj = rand_graph(128, 0.1, 2)
        ones = jnp.ones((128,), jnp.float32)
        got = frontier_expand(adj, ones, tile_r=64, tile_c=64)
        np.testing.assert_allclose(np.array(got), np.array(adj.sum(axis=1)))

    def test_rectangular_tiles(self):
        adj = rand_graph(256, 0.03, 3)
        f = rand_mask(256, 0.3, 4)
        got = frontier_expand(adj, f, tile_r=128, tile_c=64)
        want = ref.frontier_expand_ref(adj, f)
        np.testing.assert_allclose(np.array(got), np.array(want))

    def test_rejects_misaligned_tile(self):
        adj = rand_graph(128, 0.05, 5)
        f = rand_mask(128, 0.2, 6)
        with pytest.raises(AssertionError):
            frontier_expand(adj, f, tile_r=100, tile_c=100)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        density=st.floats(0.0, 0.3),
        fp=st.floats(0.0, 1.0),
    )
    def test_hypothesis_sweep(self, seed, density, fp):
        n = 128
        adj = rand_graph(n, density, seed)
        f = rand_mask(n, fp, seed ^ 0xABCD)
        got = frontier_expand(adj, f, tile_r=64, tile_c=64)
        want = ref.frontier_expand_ref(adj, f)
        np.testing.assert_allclose(np.array(got), np.array(want))

    def test_vmem_estimate_reasonable(self):
        # 128x128 f32 tile double-buffered: ~132KB << 16MB VMEM.
        assert vmem_bytes(128, 128) < 16 * 2**20
        assert vmem_bytes(128, 128) == 2 * (128 * 128 + 128 + 128) * 4


class TestBitmapUpdate:
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("tile", TILES)
    def test_matches_ref(self, n, tile):
        counts = jnp.array(
            np.random.default_rng(n).integers(0, 4, n).astype(np.float32)
        )
        visited = rand_mask(n, 0.4, n + 1)
        level = jnp.where(visited > 0, 1.0, ref.INF_LEVEL).astype(jnp.float32)
        bl = jnp.array([3.0], jnp.float32)
        got = bitmap_update(counts, visited, level, bl, tile=tile)
        want = ref.bitmap_update_ref(counts, visited, level, bl)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.array(g), np.array(w))

    def test_visited_is_monotone(self):
        n = 128
        counts = rand_mask(n, 0.5, 7) * 3.0
        visited = rand_mask(n, 0.5, 8)
        level = jnp.where(visited > 0, 0.0, ref.INF_LEVEL).astype(jnp.float32)
        _, v2, _ = bitmap_update(counts, visited, level, jnp.array([0.0]), tile=64)
        assert np.all(np.array(v2) >= np.array(visited))
        assert set(np.unique(np.array(v2))).issubset({0.0, 1.0})

    def test_already_visited_never_reactivated(self):
        n = 128
        counts = jnp.ones((n,), jnp.float32)
        visited = jnp.ones((n,), jnp.float32)
        level = jnp.zeros((n,), jnp.float32)
        nf, v2, l2 = bitmap_update(counts, visited, level, jnp.array([5.0]), tile=64)
        assert float(jnp.sum(nf)) == 0.0
        np.testing.assert_allclose(np.array(l2), np.array(level))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), lvl=st.integers(0, 100))
    def test_hypothesis_sweep(self, seed, lvl):
        n = 128
        rng = np.random.default_rng(seed)
        counts = jnp.array(rng.integers(0, 3, n).astype(np.float32))
        visited = rand_mask(n, 0.3, seed ^ 0x55)
        level = jnp.where(visited > 0, float(max(lvl - 1, 0)), ref.INF_LEVEL).astype(
            jnp.float32
        )
        bl = jnp.array([float(lvl)], jnp.float32)
        got = bitmap_update(counts, visited, level, bl, tile=64)
        want = ref.bitmap_update_ref(counts, visited, level, bl)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.array(g), np.array(w))


class TestPopcount:
    @pytest.mark.parametrize("n", SIZES)
    def test_matches_ref(self, n):
        x = rand_mask(n, 0.37, n)
        got = popcount(x, tile=64)
        np.testing.assert_allclose(np.array(got), np.array(ref.popcount_ref(x)))

    def test_zero_and_full(self):
        n = 128
        assert float(popcount(jnp.zeros((n,), jnp.float32), tile=64)[0]) == 0.0
        assert float(popcount(jnp.ones((n,), jnp.float32), tile=64)[0]) == float(n)
