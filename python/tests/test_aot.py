"""AOT path checks: HLO text artifacts are well-formed, the manifest is
consistent, and the lowered module exposes the agreed signature."""

import os

import pytest

from compile.aot import build, lower_bfs_step, SIZES, TILE


@pytest.fixture(scope="module")
def hlo_256():
    return lower_bfs_step(256, TILE)


class TestLowering:
    def test_hlo_text_is_module(self, hlo_256):
        assert hlo_256.startswith("HloModule")

    def test_signature_matches_contract(self, hlo_256):
        # 5 inputs: adj (n,n) + 3 vectors + bfs_level (1,); 4 outputs.
        head = hlo_256.splitlines()[0]
        assert "f32[256,256]" in head
        assert head.count("f32[256]{0}") >= 4  # 3 in + 3 out vectors
        assert head.count("f32[1]{0}") == 2  # bfs_level in, num_new out

    def test_no_custom_calls(self, hlo_256):
        # interpret=True must lower to plain HLO the CPU client can run
        # (a Mosaic custom-call would break the Rust side).
        assert "custom-call" not in hlo_256 or "mosaic" not in hlo_256.lower()

    def test_deterministic_lowering(self):
        a = lower_bfs_step(256, TILE)
        b = lower_bfs_step(256, TILE)
        assert a == b


class TestBuild:
    def test_build_writes_manifest_and_files(self, tmp_path):
        out = tmp_path / "artifacts"
        files = build(str(out), sizes=(256,), tile=TILE)
        assert (out / "bfs_step_n256.hlo.txt").exists()
        assert (out / "bfs_full_n256.hlo.txt").exists()
        manifest = (out / "manifest.txt").read_text().strip().splitlines()
        rows = [l for l in manifest if not l.startswith("#")]
        assert len(rows) == 2  # bfs_step + bfs_full
        names = set()
        for row in rows:
            name, n, tile, fname = row.split("\t")
            names.add(name)
            assert int(n) == 256
            assert int(tile) == min(TILE, 256)
            assert any(os.path.basename(f) == fname for f in files)
        assert names == {"bfs_step", "bfs_full"}

    def test_default_sizes_cover_small_graphs(self):
        assert 256 in SIZES and max(SIZES) >= 2048
